//! Incremental-decode parity gate: KV-cached sessions must replay the
//! full-recompute decode loop exactly.
//!
//! Three execution paths generate greedy token streams over the same
//! models and prompts:
//!
//! 1. **reference** — the old full-recompute loop, inlined here: rebuild
//!    the (keep-tail-windowed) sequence every step, run the compiled
//!    full-sequence forward, read logits at the last live position;
//! 2. **compiled incremental** — `CompiledModel`'s `session_round`
//!    override, reached here through the `prefill`/`decode` sugar and
//!    directly as multi-slot layer-major rounds (per-layer K/V caches,
//!    one-position attention, cross-slot expert-gather, window-slide
//!    invalidation + re-prefill);
//! 3. **dense fallback** — the `Backend` default session methods
//!    (full recompute through `fwd_logits_routed` on a right-sized
//!    batch).
//!
//! The streams must be **identical** (greedy decode leaves no tolerance
//! to hide in), including generations that overflow `seq` and slide the
//! window — the cache-invalidation edge. Last-position logits are pinned
//! at 1e-5 between the incremental and recompute paths.

use stun::data::BOS;
use stun::model::{ModelConfig, ParamSet};
use stun::pruning::unstructured;
use stun::quant::QuantScheme;
use stun::runtime::session::{greedy_token, recompute_step};
use stun::runtime::{Backend, CompiledForward, DecodeState, NativeBackend};
use stun::sparse::SparseConfig;
use stun::tensor::IntTensor;

fn tiny() -> NativeBackend {
    NativeBackend::new(ModelConfig::test_tiny())
}

/// Model variants the session paths must agree on: unpruned dense,
/// 70%-unstructured (CSR kernels engaged), and expert-pruned.
fn model_variants(cfg: &ModelConfig) -> Vec<(&'static str, ParamSet)> {
    let base = ParamSet::init(cfg, 41);
    let mut sparse = base.clone();
    unstructured::magnitude_prune(&mut sparse, 0.7).unwrap();
    let mut dead = base.clone();
    dead.prune_expert(0, 1);
    dead.prune_expert(1, 2);
    vec![("dense", base), ("csr-0.7", sparse), ("expert-pruned", dead)]
}

/// The pre-session decode loop, verbatim: full forward over the padded
/// window every step, logits at the last live position, greedy next
/// token (never PAD), keep-tail window slide at `seq` overflow.
fn reference_stream(
    exec: &dyn CompiledForward,
    prompt: &[i32],
    n_tokens: usize,
) -> (Vec<i32>, Vec<f32>) {
    let cfg = exec.config().clone();
    let (s, v) = (cfg.seq, cfg.vocab);
    let mut seq: Vec<i32> = prompt.to_vec();
    if seq.is_empty() {
        seq.push(BOS);
    }
    let mut out = Vec::new();
    let mut last_logits = Vec::new();
    for _ in 0..n_tokens {
        let mut win = seq.clone();
        if win.len() >= s {
            win.drain(0..win.len() - (s - 1));
        }
        let mut tokens = IntTensor::zeros(&[1, s]);
        tokens.row_mut(0)[..win.len()].copy_from_slice(&win);
        let (logits, _) = exec.fwd_logits_routed(&tokens).unwrap();
        let pos = win.len() - 1;
        let row = &logits.data()[pos * v..(pos + 1) * v];
        last_logits = row.to_vec();
        let tok = greedy_token(row);
        out.push(tok);
        seq.push(tok);
    }
    (out, last_logits)
}

/// Greedy stream through a session (`prefill` + one-token `decode`s),
/// returning the tokens and the final step's logits row.
fn session_stream<P, D>(
    mut state: DecodeState,
    mut prefill: P,
    mut decode: D,
    prompt: &[i32],
    n_tokens: usize,
) -> (Vec<i32>, Vec<f32>)
where
    P: FnMut(&mut DecodeState, &[i32]) -> stun::prelude::Result<stun::runtime::StepOutput>,
    D: FnMut(&mut DecodeState, i32) -> stun::prelude::Result<stun::runtime::StepOutput>,
{
    let out0 = prefill(&mut state, prompt).unwrap();
    assert_eq!(out0.logits.shape()[0], 1, "prefill returns one row per slot");
    let mut last_logits = out0.logits.row(0).to_vec();
    let mut toks = vec![greedy_token(out0.logits.row(0))];
    for _ in 1..n_tokens {
        let out = decode(&mut state, *toks.last().unwrap()).unwrap();
        assert_eq!(
            out.logits.shape()[0],
            1,
            "a single active sequence must never pay for padding rows"
        );
        last_logits = out.logits.row(0).to_vec();
        toks.push(greedy_token(out.logits.row(0)));
    }
    (toks, last_logits)
}

fn assert_streams_match(cfg_name: &str, prompt_len: usize, n_tokens: usize) {
    let backend = tiny();
    let cfg = backend.config().clone();
    for (label, params) in model_variants(&cfg) {
        let compiled = backend.compile(&params).unwrap().expect("native compiles");
        let prompt: Vec<i32> = (0..prompt_len as i32).map(|i| 2 + (i % 37)).collect();

        let (want, want_logits) = reference_stream(compiled.as_ref(), &prompt, n_tokens);

        // compiled incremental (KV-cached session)
        let (inc, inc_logits) = session_stream(
            compiled.new_session(1),
            |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
            |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
            &prompt,
            n_tokens,
        );
        assert_eq!(
            inc, want,
            "[{cfg_name}/{label}] incremental diverged from full recompute"
        );
        for (a, b) in inc_logits.iter().zip(&want_logits) {
            assert!(
                (a - b).abs() <= 1e-5,
                "[{cfg_name}/{label}] last-position logits drifted: {a} vs {b}"
            );
        }

        // dense Backend fallback session (full recompute per step)
        let (dense, dense_logits) = session_stream(
            backend.new_session(1),
            |st: &mut DecodeState, p: &[i32]| backend.prefill(&params, st, 0, p),
            |st: &mut DecodeState, t: i32| backend.decode(&params, st, &[(0, t)]),
            &prompt,
            n_tokens,
        );
        assert_eq!(
            dense, want,
            "[{cfg_name}/{label}] dense fallback diverged from full recompute"
        );
        for (a, b) in dense_logits.iter().zip(&want_logits) {
            assert!(
                (a - b).abs() <= 1e-5,
                "[{cfg_name}/{label}] dense last-position logits drifted: {a} vs {b}"
            );
        }
    }
}

#[test]
fn incremental_matches_recompute_within_the_window() {
    // prompt + generation fit comfortably inside seq=64: every decode
    // step after prefill is a genuine one-position increment
    assert_streams_match("in-window", 12, 8);
}

#[test]
fn window_slide_keeps_all_paths_identical() {
    // prompt of seq−3 plus 8 tokens crosses seq: the history overflows,
    // the window slides every subsequent step, and the incremental path
    // must invalidate + re-prefill to stay byte-identical
    let s = ModelConfig::test_tiny().seq;
    assert_streams_match("window-slide", s - 3, 8);
}

#[test]
fn oversized_prompts_window_like_the_recompute_path() {
    // a prompt already longer than seq is windowed to its last seq−1
    // tokens at prefill time, exactly like the recompute loop
    let s = ModelConfig::test_tiny().seq;
    assert_streams_match("long-prompt", s + 9, 5);
}

#[test]
fn empty_prompt_gets_bos_on_every_path() {
    assert_streams_match("empty-prompt", 0, 4);
}

#[test]
fn batched_decode_rows_match_single_slot_streams() {
    // Two slots stepped together must produce the same streams as each
    // stepped alone — the batched gather may regroup work across slots
    // but never change per-token arithmetic.
    let backend = tiny();
    let params = ParamSet::init(backend.config(), 43);
    let compiled = backend.compile(&params).unwrap().unwrap();
    let pa: Vec<i32> = (0..10).map(|i| 3 + (i % 11)).collect();
    let pb: Vec<i32> = (0..17).map(|i| 5 + (i % 7)).collect();
    let n = 6;

    let (solo_a, _) = session_stream(
        compiled.new_session(1),
        |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
        |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
        &pa,
        n,
    );
    let (solo_b, _) = session_stream(
        compiled.new_session(1),
        |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
        |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
        &pb,
        n,
    );

    let mut state = compiled.new_session(2);
    let oa = compiled.prefill(&mut state, 0, &pa).unwrap();
    let ob = compiled.prefill(&mut state, 1, &pb).unwrap();
    let mut ta = greedy_token(oa.logits.row(0));
    let mut tb = greedy_token(ob.logits.row(0));
    let (mut got_a, mut got_b) = (vec![ta], vec![tb]);
    for _ in 1..n {
        let out = compiled.decode(&mut state, &[(0, ta), (1, tb)]).unwrap();
        assert_eq!(out.logits.shape()[0], 2);
        let r = out.routing.as_ref().expect("compiled path exposes routing");
        assert_eq!(r.shape(), &[backend.config().n_layers, 2, backend.config().top_k]);
        ta = greedy_token(out.logits.row(0));
        tb = greedy_token(out.logits.row(1));
        got_a.push(ta);
        got_b.push(tb);
    }
    assert_eq!(got_a, solo_a);
    assert_eq!(got_b, solo_b);
}

#[test]
fn batched_rounds_match_sequential_and_recompute_f32_and_u16() {
    // Three slots stepped in one layer-major round per token must
    // reproduce (a) the sequential single-slot session streams and
    // (b) the full-recompute reference through the same executor —
    // token-identical, last-position logits within 1e-5 — for f32 and
    // u16 storage alike (the batched dequant temp row must not change
    // the reduction).
    let backend = tiny();
    let cfg = backend.config().clone();
    let mut params = ParamSet::init(&cfg, 59);
    unstructured::magnitude_prune(&mut params, 0.7).unwrap();
    let prompts: [Vec<i32>; 3] = [
        (0..9).map(|i| 2 + (i % 13)).collect(),
        (0..14).map(|i| 4 + (i % 19)).collect(),
        (0..5).map(|i| 6 + (i % 5)).collect(),
    ];
    let n = 7;
    for quant in [QuantScheme::F32, QuantScheme::U16] {
        let scfg = SparseConfig {
            quant,
            ..Default::default()
        };
        let compiled = backend.compile_with(&params, &scfg).unwrap().unwrap();

        // batched: one round prefills all three, then decode rounds
        let mut state = compiled.new_session(3);
        for (i, p) in prompts.iter().enumerate() {
            state.begin(i, p);
        }
        let slots = [0usize, 1, 2];
        let out = compiled.session_round(&mut state, &slots).unwrap();
        assert_eq!(out.logits.shape()[0], 3, "one logits row per slot");
        let mut toks: Vec<i32> =
            (0..3).map(|i| greedy_token(out.logits.row(i))).collect();
        let mut got: Vec<Vec<i32>> = toks.iter().map(|&t| vec![t]).collect();
        let mut last: Vec<Vec<f32>> =
            (0..3).map(|i| out.logits.row(i).to_vec()).collect();
        for _ in 1..n {
            for (i, &t) in toks.iter().enumerate() {
                state.push(i, t);
            }
            let out = compiled.session_round(&mut state, &slots).unwrap();
            for i in 0..3 {
                toks[i] = greedy_token(out.logits.row(i));
                got[i].push(toks[i]);
                last[i] = out.logits.row(i).to_vec();
            }
        }

        for (i, p) in prompts.iter().enumerate() {
            let q = quant.name();
            let (solo, solo_logits) = session_stream(
                compiled.new_session(1),
                |st: &mut DecodeState, pr: &[i32]| compiled.prefill(st, 0, pr),
                |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
                p,
                n,
            );
            assert_eq!(got[i], solo, "[{q}/slot {i}] batched != sequential");
            for (a, b) in last[i].iter().zip(&solo_logits) {
                assert!(
                    (a - b).abs() <= 1e-5,
                    "[{q}/slot {i}] batched logits drifted from sequential: {a} vs {b}"
                );
            }
            let (want, want_logits) = reference_stream(compiled.as_ref(), p, n);
            assert_eq!(got[i], want, "[{q}/slot {i}] batched != full recompute");
            for (a, b) in last[i].iter().zip(&want_logits) {
                assert!(
                    (a - b).abs() <= 1e-5,
                    "[{q}/slot {i}] batched logits drifted from recompute: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn mid_round_slide_in_one_slot_keeps_others_cached() {
    // One slot's history crosses `seq` mid-generation — its window
    // slides and the plan re-prefills it — while the other slot must
    // keep stepping incrementally off its warm cache in the same
    // rounds. Streams stay identical to the solo sessions throughout.
    let backend = tiny();
    let cfg = backend.config().clone();
    let params = ParamSet::init(&cfg, 61);
    let compiled = backend.compile(&params).unwrap().unwrap();
    let s = cfg.seq;
    let pa: Vec<i32> = (0..s as i32 - 2).map(|i| 2 + (i % 23)).collect();
    let pb: Vec<i32> = (0..8).map(|i| 3 + (i % 5)).collect();
    let n = 6;

    let (solo_a, _) = session_stream(
        compiled.new_session(1),
        |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
        |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
        &pa,
        n,
    );
    let (solo_b, _) = session_stream(
        compiled.new_session(1),
        |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
        |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
        &pb,
        n,
    );

    let mut state = compiled.new_session(2);
    state.begin(0, &pa);
    state.begin(1, &pb);
    let out = compiled.session_round(&mut state, &[0, 1]).unwrap();
    let mut ta = greedy_token(out.logits.row(0));
    let mut tb = greedy_token(out.logits.row(1));
    let (mut got_a, mut got_b) = (vec![ta], vec![tb]);
    let mut slid_rounds = 0;
    for _ in 1..n {
        let b_cached = state.cached_len(1);
        state.push(0, ta);
        state.push(1, tb);
        let out = compiled.session_round(&mut state, &[0, 1]).unwrap();
        assert_eq!(
            state.cached_len(1),
            b_cached + 1,
            "slot 1 must stay incremental (one new cached position per round)"
        );
        if state.slid(0) {
            slid_rounds += 1;
        }
        ta = greedy_token(out.logits.row(0));
        tb = greedy_token(out.logits.row(1));
        got_a.push(ta);
        got_b.push(tb);
    }
    assert!(slid_rounds > 0, "slot 0 never crossed the window boundary");
    assert!(!state.slid(1), "slot 1 must not have slid");
    assert_eq!(got_a, solo_a, "sliding slot diverged from its solo stream");
    assert_eq!(got_b, solo_b, "cached slot diverged from its solo stream");
}

#[test]
fn mixed_prefill_and_decode_share_a_round() {
    // A slot joining late contributes a multi-token prefill to the same
    // layer-major sweep in which an established slot decodes one token.
    // Both streams must match their solo sessions exactly.
    let backend = tiny();
    let params = ParamSet::init(backend.config(), 67);
    let compiled = backend.compile(&params).unwrap().unwrap();
    let pa: Vec<i32> = (0..11).map(|i| 2 + (i % 17)).collect();
    let pb: Vec<i32> = (0..13).map(|i| 7 + (i % 3)).collect();
    let n = 5;

    let (solo_a, _) = session_stream(
        compiled.new_session(1),
        |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
        |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
        &pa,
        n,
    );
    let (solo_b, _) = session_stream(
        compiled.new_session(1),
        |st: &mut DecodeState, p: &[i32]| compiled.prefill(st, 0, p),
        |st: &mut DecodeState, t: i32| compiled.decode(st, &[(0, t)]),
        &pb,
        n,
    );

    let mut state = compiled.new_session(2);
    let out = compiled.prefill(&mut state, 0, &pa).unwrap();
    let mut ta = greedy_token(out.logits.row(0));
    let mut got_a = vec![ta];
    // round 2: slot 0's one-token decode + slot 1's 13-token prefill
    state.push(0, ta);
    state.begin(1, &pb);
    let out = compiled.session_round(&mut state, &[0, 1]).unwrap();
    assert_eq!(out.logits.shape()[0], 2);
    ta = greedy_token(out.logits.row(0));
    let mut tb = greedy_token(out.logits.row(1));
    got_a.push(ta);
    let mut got_b = vec![tb];
    for _ in 2..n {
        state.push(0, ta);
        state.push(1, tb);
        let out = compiled.session_round(&mut state, &[0, 1]).unwrap();
        ta = greedy_token(out.logits.row(0));
        tb = greedy_token(out.logits.row(1));
        got_a.push(ta);
        got_b.push(tb);
    }
    assert_eq!(got_a, solo_a, "decoding slot diverged when sharing rounds");
    assert_eq!(
        got_b,
        solo_b[..n - 1],
        "late-joining slot diverged from its solo stream"
    );
}

#[test]
fn recompute_step_sizes_batch_to_stepped_slots() {
    // the shared fallback builds [n, seq] from the stepped slots — a
    // single slot means one row, regardless of eval_batch
    let backend = tiny();
    let cfg = backend.config().clone();
    let params = ParamSet::init(&cfg, 47);
    let mut state = DecodeState::new(&cfg, cfg.eval_batch);
    state.begin(3, &[4, 5, 6]);
    let out = recompute_step(&cfg, &state, &[3], |t| {
        assert_eq!(t.shape(), &[1, cfg.seq], "batch must be sized to the active set");
        backend.fwd_logits_routed(&params, t)
    })
    .unwrap();
    assert_eq!(out.logits.shape(), &[1, cfg.vocab]);
    let r = out.routing.expect("native backend exposes routing");
    assert_eq!(r.shape(), &[cfg.n_layers, 1, cfg.top_k]);
}

#[test]
fn session_routing_matches_full_forward_routing() {
    // prefill's [L, 1, K] routing must equal the full forward's routing
    // at the prompt's last position
    let backend = tiny();
    let cfg = backend.config().clone();
    let mut params = ParamSet::init(&cfg, 53);
    params.prune_expert(0, 0);
    let compiled = backend.compile(&params).unwrap().unwrap();
    let prompt: Vec<i32> = (0..9).map(|i| 2 + i).collect();

    let mut state = compiled.new_session(1);
    let out = compiled.prefill(&mut state, 0, &prompt).unwrap();
    let sess_r = out.routing.expect("routing");

    let mut tokens = IntTensor::zeros(&[1, cfg.seq]);
    tokens.row_mut(0)[..prompt.len()].copy_from_slice(&prompt);
    let (_, full_r) = compiled.fwd_logits_routed(&tokens).unwrap();
    let full_r = full_r.expect("routing");
    let pos = prompt.len() - 1;
    for l in 0..cfg.n_layers {
        for k in 0..cfg.top_k {
            // sess_r is [L, 1, K]; full_r is [L, B·S, K] with B = 1
            assert_eq!(
                sess_r.data()[l * cfg.top_k + k],
                full_r.data()[(l * cfg.seq + pos) * cfg.top_k + k],
                "layer {l} slot {k}"
            );
        }
    }
}
