//! Integration tests: cross-layer flows through the execution backend on
//! the `tiny` config. These are the composition guarantees the unit tests
//! cannot give: masked execution ≡ physical expert removal, training
//! actually learns, the full STUN pipeline holds its sparsity contract
//! end to end, and serving drains a request queue on a pruned model.
//!
//! Everything here runs unconditionally on [`NativeBackend`] — no
//! artifacts, no PJRT. The `pjrt`-feature module at the bottom adds the
//! artifact-path variants (kernel vs reference graphs, native-vs-PJRT
//! equivalence); those skip cleanly when the artifacts or the PJRT
//! runtime are absent.

use stun::coordinator::{burst_workload, Batcher, ExpertStore};
use stun::data::{CorpusConfig, CorpusGenerator};
use stun::eval::EvalHarness;
use stun::model::{ModelConfig, ParamSet};
use stun::pruning::combinatorial;
use stun::pruning::expert::{ExpertPruneConfig, ExpertPruner};
use stun::pruning::unstructured::UnstructuredConfig;
use stun::pruning::StunPipeline;
use stun::runtime::{Backend, NativeBackend};
use stun::tensor::Tensor;
use stun::train::{TrainConfig, Trainer};

fn tiny() -> NativeBackend {
    NativeBackend::new(ModelConfig::test_tiny())
}

fn corpus(backend: &dyn Backend, seed: u64) -> CorpusGenerator {
    CorpusGenerator::new(CorpusConfig::for_vocab(
        backend.config().vocab,
        backend.config().seq,
        seed,
    ))
}

#[test]
fn expert_mask_equals_physical_removal_in_layer_recon() {
    // Run layer_recon with expert e masked vs with e's weights zeroed:
    // outputs must match, because the mask adds -1e9 to the router logit
    // (exactly "not in the softmax").
    let backend = tiny();
    let cfg = backend.config().clone();
    let mut rng = stun::util::rng::Rng::new(5);
    let router = Tensor::randn(&[cfg.n_experts, cfg.d_model], &mut rng);
    let w1 = Tensor::randn(&[cfg.n_experts, cfg.d_model, cfg.d_ff], &mut rng);
    let w2 = Tensor::randn(&[cfg.n_experts, cfg.d_ff, cfg.d_model], &mut rng);
    let x = Tensor::randn(&[backend.recon_tokens(), cfg.d_model], &mut rng);

    let mut mask = Tensor::ones(&[cfg.n_experts]);
    mask.data_mut()[1] = 0.0;
    let full = Tensor::ones(&[cfg.n_experts]);
    let y_masked = backend.layer_recon(&router, &w1, &w2, &mask, &x).unwrap();
    let y_full = backend.layer_recon(&router, &w1, &w2, &full, &x).unwrap();
    // masking must change the output (expert 1 carried real traffic)…
    assert!(y_masked.fro_dist(&y_full) > 1e-3);
    // …and a masked expert's weights are irrelevant: zeroing them changes
    // nothing (this IS the physical-removal equivalence).
    let mut w1_zero = w1.clone();
    w1_zero.subtensor_mut(1).fill(0.0);
    let mut w2_zero = w2.clone();
    w2_zero.subtensor_mut(1).fill(0.0);
    let y_masked_zeroed = backend
        .layer_recon(&router, &w1_zero, &w2_zero, &mask, &x)
        .unwrap();
    let d = y_masked.fro_dist(&y_masked_zeroed);
    assert!(d < 1e-4, "masked expert weights leaked into output: {d}");
}

#[test]
fn training_reduces_loss_and_improves_perplexity() {
    let backend = tiny();
    let mut params = ParamSet::init(backend.config(), 3);
    let untrained = params.clone();
    let mut gen = corpus(&backend, 4);
    let trainer = Trainer::new(TrainConfig {
        steps: 60,
        log_every: 10,
        ..Default::default()
    });
    let log = trainer.train(&backend, &mut params, &mut gen).unwrap();
    assert!(
        log.last_loss() < log.first_loss() - 0.5,
        "loss {} -> {}",
        log.first_loss(),
        log.last_loss()
    );
    let mut held_out = corpus(&backend, 777);
    let h_trained = EvalHarness::new(&backend, &params).unwrap();
    let ppl_trained = h_trained.perplexity(&mut held_out, 2).unwrap();
    drop(h_trained);
    let h_raw = EvalHarness::new(&backend, &untrained).unwrap();
    let mut held_out2 = corpus(&backend, 777);
    let ppl_raw = h_raw.perplexity(&mut held_out2, 2).unwrap();
    assert!(
        ppl_trained < ppl_raw * 0.5,
        "perplexity {ppl_raw} -> {ppl_trained}"
    );
}

#[test]
fn stun_pipeline_hits_total_sparsity_and_stays_runnable() {
    let backend = tiny();
    let mut params = ParamSet::init(backend.config(), 5);
    let mut gen = corpus(&backend, 6);
    let report = StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: 0.5,
        calib_batches: 2,
    }
    .run(&backend, &mut params, &mut gen)
    .unwrap();
    assert!(
        (report.final_sparsity - 0.5).abs() < 0.03,
        "final sparsity {}",
        report.final_sparsity
    );
    let expert_report = report.expert_report.as_ref().unwrap();
    // λ₂ = 0 ⇒ the expert-pruning decision cost zero forward passes
    assert_eq!(expert_report.decision_forward_passes, 0);
    // pruned model still evaluates
    let h = EvalHarness::new(&backend, &params).unwrap();
    let r = h.full_report(9, 4, 6, 1).unwrap();
    for (name, v) in &r.rows {
        assert!((0.0..=100.0).contains(v), "{name} {v}");
    }
}

#[test]
fn full_pipeline_then_serve_on_native_backend() {
    // The acceptance flow: StunPipeline::run → eval → Batcher::serve,
    // entirely on the native backend.
    let backend = tiny();
    let mut params = ParamSet::init(backend.config(), 15);
    let mut gen = corpus(&backend, 16);
    StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: 0.4,
        calib_batches: 2,
    }
    .run(&backend, &mut params, &mut gen)
    .unwrap();

    let h = EvalHarness::new(&backend, &params).unwrap();
    let report = h.full_report(17, 4, 4, 1).unwrap();
    assert!(!report.rows.is_empty());
    drop(h);

    let store = ExpertStore::new(
        ExpertStore::working_set_bytes(&params, stun::quant::QuantScheme::F32),
        std::time::Duration::from_micros(50),
    );
    let mut batcher = Batcher::new(&backend, &params, store).unwrap();
    let queue = burst_workload(backend.config(), 6, 4, 19);
    let (responses, metrics) = batcher.serve(queue).unwrap();
    assert_eq!(responses.len(), 6);
    assert_eq!(metrics.completed, 6);
    // native backend drove the store with real router decisions
    assert_eq!(metrics.routed_steps, metrics.decode_steps);
}

#[test]
fn combinatorial_matches_exhaustive_definition_at_n4() {
    // At n=4 / prune 1, the combinatorial baseline must pick the expert
    // whose removal minimises Eq. 4 — verify against a manual scan.
    let backend = tiny();
    let mut params = ParamSet::init(backend.config(), 7);
    let mut gen = corpus(&backend, 8);
    let inputs = combinatorial::capture_moe_inputs(&backend, &params, &mut gen).unwrap();

    // manual scan on layer 0
    let n = backend.config().n_experts;
    let y_full = backend
        .layer_recon(
            params.router(0),
            params.w1(0),
            params.w2(0),
            &Tensor::ones(&[n]),
            &inputs[0],
        )
        .unwrap();
    let mut best = (f64::INFINITY, usize::MAX);
    for e in 0..n {
        let mut mask = Tensor::ones(&[n]);
        mask.data_mut()[e] = 0.0;
        let y = backend
            .layer_recon(params.router(0), params.w1(0), params.w2(0), &mask, &inputs[0])
            .unwrap();
        let loss = y_full.fro_dist(&y);
        if loss < best.0 {
            best = (loss, e);
        }
    }

    let report =
        combinatorial::prune_combinatorial(&backend, &mut params, &inputs, 1).unwrap();
    assert_eq!(report.pruned[0], vec![best.1]);
    assert!((report.losses[0] - best.0).abs() < 1e-6);
    assert!(report.forward_passes >= (n as u64 + 1) * backend.config().n_layers as u64);
}

#[test]
fn ours_beats_or_matches_random_expert_choice_on_reconstruction() {
    // Sanity on the Taylor ranking: our O(1) choice should give lower
    // layer-0 reconstruction loss than the WORST choice of the same size.
    let backend = tiny();
    let params = ParamSet::init(backend.config(), 9);
    let mut gen = corpus(&backend, 10);
    let inputs = combinatorial::capture_moe_inputs(&backend, &params, &mut gen).unwrap();
    let n = backend.config().n_experts;
    let run_mask = |mask: &Tensor| -> f64 {
        let y = backend
            .layer_recon(params.router(0), params.w1(0), params.w2(0), mask, &inputs[0])
            .unwrap();
        let y_full = backend
            .layer_recon(
                params.router(0),
                params.w1(0),
                params.w2(0),
                &Tensor::ones(&[n]),
                &inputs[0],
            )
            .unwrap();
        y_full.fro_dist(&y)
    };

    let mut ours = params.clone();
    ExpertPruner::prune(
        &mut ours,
        None,
        &ExpertPruneConfig {
            ratio: 0.5,
            ..Default::default()
        },
    );
    let mut ours_mask = Tensor::ones(&[n]);
    for e in 0..n {
        if !ours.is_expert_alive(0, e) {
            ours_mask.data_mut()[e] = 0.0;
        }
    }
    let ours_loss = run_mask(&ours_mask);

    // worst over all 2-subsets
    let mut worst = 0.0f64;
    for subset in combinatorial::subsets(n, 2) {
        let mut mask = Tensor::ones(&[n]);
        for &e in &subset {
            mask.data_mut()[e] = 0.0;
        }
        worst = worst.max(run_mask(&mask));
    }
    assert!(
        ours_loss <= worst + 1e-9,
        "ours {ours_loss} vs worst {worst}"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_eval_scores() {
    let backend = tiny();
    let mut params = ParamSet::init(backend.config(), 11);
    params.prune_expert(0, 2);
    let path = std::env::temp_dir().join(format!("stun-it-{}.stz", std::process::id()));
    params.to_checkpoint("{}").save(&path).unwrap();
    let loaded = ParamSet::from_checkpoint(
        backend.config(),
        &stun::checkpoint::Checkpoint::load(&path).unwrap(),
    )
    .unwrap();
    std::fs::remove_file(&path).ok();

    let h1 = EvalHarness::new(&backend, &params).unwrap();
    let mut suite = stun::eval::TaskSuite::new(
        backend.config().vocab,
        backend.config().seq,
        13,
    );
    let items = suite.mc_items(stun::eval::TaskKind::MmluLike, 8);
    let a = h1.score_mc(&items).unwrap();
    drop(h1);
    let h2 = EvalHarness::new(&backend, &loaded).unwrap();
    let b = h2.score_mc(&items).unwrap();
    assert_eq!(a, b);
}

// ===========================================================================
// PJRT-gated tests: artifact execution + cross-backend equivalence.
// ===========================================================================

#[cfg(feature = "pjrt")]
mod pjrt_gated {
    use super::*;
    use stun::runtime::{self, PjrtBackend};

    /// Load the PJRT backend for the tiny artifact bundle, or None when
    /// the artifacts or the PJRT runtime (real `xla` crate + libraries)
    /// are unavailable — these tests then skip, exactly like the
    /// artifact-missing skip the suite had before the native backend.
    fn pjrt_tiny() -> Option<PjrtBackend> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return None;
        }
        match PjrtBackend::load(&dir) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn kernel_and_reference_artifacts_agree() {
        let Some(backend) = pjrt_tiny() else { return };
        let bundle = backend.bundle();
        let params = ParamSet::init(&bundle.config, 1);
        let mut gen = CorpusGenerator::new(CorpusConfig::for_vocab(
            bundle.config.vocab,
            bundle.config.seq,
            2,
        ));
        let (tokens, targets) = gen.batch(bundle.config.eval_batch);
        let mut args = runtime::pjrt::params_to_literals(&params).unwrap();
        args.push(runtime::pjrt::expert_mask_literal(&params).unwrap());
        args.push(runtime::pjrt::int_tensor_to_literal(&tokens).unwrap());
        args.push(runtime::pjrt::int_tensor_to_literal(&targets).unwrap());
        let ref_out = bundle.artifact("fwd_loss").unwrap().run(&args).unwrap();
        let kern_out = bundle
            .artifact("fwd_loss_kernel")
            .unwrap()
            .run(&args)
            .unwrap();
        let ref_loss = runtime::pjrt::literal_to_f32(&ref_out[0]).unwrap();
        let kern_loss = runtime::pjrt::literal_to_f32(&kern_out[0]).unwrap();
        assert!(
            (ref_loss - kern_loss).abs() < 1e-3,
            "kernel {kern_loss} vs ref {ref_loss}"
        );
        // per-token logp agree too
        let ref_lp = runtime::pjrt::literal_to_tensor(&ref_out[3]).unwrap();
        let kern_lp = runtime::pjrt::literal_to_tensor(&kern_out[3]).unwrap();
        let max_diff = ref_lp
            .data()
            .iter()
            .zip(kern_lp.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 5e-3, "max tok_logp diff {max_diff}");
    }

    /// Cross-backend equivalence: the native reference implementation and
    /// the AOT artifacts must produce the same logits for the same
    /// parameters — this pins the NativeBackend semantics to the compiled
    /// python graph.
    #[test]
    fn native_and_pjrt_fwd_logits_agree() {
        let Some(pjrt) = pjrt_tiny() else { return };
        let native = NativeBackend::new(pjrt.config().clone());
        let mut params = ParamSet::init(pjrt.config(), 23);
        params.prune_expert(0, 1); // exercise the mask path too
        let mut gen = CorpusGenerator::new(CorpusConfig::for_vocab(
            pjrt.config().vocab,
            pjrt.config().seq,
            24,
        ));
        let (tokens, targets) = gen.batch(pjrt.config().eval_batch);

        let l_native = native.fwd_logits(&params, &tokens).unwrap();
        let l_pjrt = pjrt.fwd_logits(&params, &tokens).unwrap();
        assert_eq!(l_native.shape(), l_pjrt.shape());
        let max_diff = l_native
            .data()
            .iter()
            .zip(l_pjrt.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-2, "max logits diff {max_diff}");

        let loss_native = native.fwd_loss(&params, &tokens, &targets).unwrap();
        let loss_pjrt = pjrt.fwd_loss(&params, &tokens, &targets).unwrap();
        assert!(
            (loss_native.mean - loss_pjrt.mean).abs() < 1e-2,
            "mean loss {} vs {}",
            loss_native.mean,
            loss_pjrt.mean
        );
        assert_eq!(loss_native.count, loss_pjrt.count);
    }
}
