//! Integration tests: cross-layer flows through the PJRT runtime on the
//! `tiny` artifact bundle. These are the composition guarantees the unit
//! tests cannot give: L1 kernel ≡ L2 reference inside compiled artifacts,
//! masked execution ≡ physical expert removal, training actually learns,
//! and the full STUN pipeline holds its sparsity contract end to end.

use stun::data::{CorpusConfig, CorpusGenerator};
use stun::eval::EvalHarness;
use stun::model::ParamSet;
use stun::pruning::combinatorial;
use stun::pruning::expert::{ExpertPruneConfig, ExpertPruner};
use stun::pruning::unstructured::UnstructuredConfig;
use stun::pruning::StunPipeline;
use stun::runtime::{self, Engine, ModelBundle};
use stun::tensor::Tensor;
use stun::train::{TrainConfig, Trainer};

fn tiny() -> Option<(Engine, ModelBundle)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let engine = Engine::new().unwrap();
    let bundle = ModelBundle::load(&engine, dir).unwrap();
    Some((engine, bundle))
}

fn corpus(bundle: &ModelBundle, seed: u64) -> CorpusGenerator {
    CorpusGenerator::new(CorpusConfig::for_vocab(
        bundle.config.vocab,
        bundle.config.seq,
        seed,
    ))
}

#[test]
fn kernel_and_reference_artifacts_agree() {
    let Some((_e, bundle)) = tiny() else { return };
    let params = ParamSet::init(&bundle.config, 1);
    let mut gen = corpus(&bundle, 2);
    let (tokens, targets) = gen.batch(bundle.config.eval_batch);
    let mut args = runtime::params_to_literals(&params).unwrap();
    args.push(runtime::expert_mask_literal(&params).unwrap());
    args.push(runtime::int_tensor_to_literal(&tokens).unwrap());
    args.push(runtime::int_tensor_to_literal(&targets).unwrap());
    let ref_out = bundle.artifact("fwd_loss").unwrap().run(&args).unwrap();
    let kern_out = bundle
        .artifact("fwd_loss_kernel")
        .unwrap()
        .run(&args)
        .unwrap();
    let ref_loss = runtime::literal_to_f32(&ref_out[0]).unwrap();
    let kern_loss = runtime::literal_to_f32(&kern_out[0]).unwrap();
    assert!(
        (ref_loss - kern_loss).abs() < 1e-3,
        "kernel {kern_loss} vs ref {ref_loss}"
    );
    // per-token logp agree too
    let ref_lp = runtime::literal_to_tensor(&ref_out[3]).unwrap();
    let kern_lp = runtime::literal_to_tensor(&kern_out[3]).unwrap();
    let max_diff = ref_lp
        .data()
        .iter()
        .zip(kern_lp.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-3, "max tok_logp diff {max_diff}");
}

#[test]
fn expert_mask_equals_physical_removal_in_layer_recon() {
    // Run layer_recon with expert e masked vs with e's weights zeroed AND
    // a router row that can never win: outputs must match, because the
    // mask adds -1e9 to the router logit (exactly "not in the softmax").
    let Some((_e, bundle)) = tiny() else { return };
    let cfg = &bundle.config;
    let mut rng = stun::util::rng::Rng::new(5);
    let router = Tensor::randn(&[cfg.n_experts, cfg.d_model], &mut rng);
    let w1 = Tensor::randn(&[cfg.n_experts, cfg.d_model, cfg.d_ff], &mut rng);
    let w2 = Tensor::randn(&[cfg.n_experts, cfg.d_ff, cfg.d_model], &mut rng);
    let x = Tensor::randn(&[bundle.recon_tokens, cfg.d_model], &mut rng);
    let art = bundle.artifact("layer_recon").unwrap();

    // masked execution
    let mut mask = Tensor::ones(&[cfg.n_experts]);
    mask.data_mut()[1] = 0.0;
    let masked = art
        .run(&[
            runtime::tensor_to_literal(&router).unwrap(),
            runtime::tensor_to_literal(&w1).unwrap(),
            runtime::tensor_to_literal(&w2).unwrap(),
            runtime::tensor_to_literal(&mask).unwrap(),
            runtime::tensor_to_literal(&x).unwrap(),
        ])
        .unwrap();

    // "physical" removal emulated with a -1e9 router logit offset
    let mut router2 = router.clone();
    for v in router2.row_mut(1) {
        *v = 0.0;
    }
    // bias cannot be expressed through weights alone for arbitrary x, so
    // instead verify via the mask path itself at full mask equality:
    let full = Tensor::ones(&[cfg.n_experts]);
    let unmasked = art
        .run(&[
            runtime::tensor_to_literal(&router).unwrap(),
            runtime::tensor_to_literal(&w1).unwrap(),
            runtime::tensor_to_literal(&w2).unwrap(),
            runtime::tensor_to_literal(&full).unwrap(),
            runtime::tensor_to_literal(&x).unwrap(),
        ])
        .unwrap();
    let y_masked = runtime::literal_to_tensor(&masked[0]).unwrap();
    let y_full = runtime::literal_to_tensor(&unmasked[0]).unwrap();
    // masking must change the output (expert 1 carried real traffic)…
    assert!(y_masked.fro_dist(&y_full) > 1e-3);
    // …and a masked expert's weights are irrelevant: zeroing them changes
    // nothing (this IS the physical-removal equivalence).
    let mut w1_zero = w1.clone();
    w1_zero.subtensor_mut(1).fill(0.0);
    let mut w2_zero = w2.clone();
    w2_zero.subtensor_mut(1).fill(0.0);
    let masked_zeroed = art
        .run(&[
            runtime::tensor_to_literal(&router).unwrap(),
            runtime::tensor_to_literal(&w1_zero).unwrap(),
            runtime::tensor_to_literal(&w2_zero).unwrap(),
            runtime::tensor_to_literal(&mask).unwrap(),
            runtime::tensor_to_literal(&x).unwrap(),
        ])
        .unwrap();
    let y_masked_zeroed = runtime::literal_to_tensor(&masked_zeroed[0]).unwrap();
    let d = y_masked.fro_dist(&y_masked_zeroed);
    assert!(d < 1e-4, "masked expert weights leaked into output: {d}");
}

#[test]
fn training_reduces_loss_and_improves_perplexity() {
    let Some((_e, bundle)) = tiny() else { return };
    let mut params = ParamSet::init(&bundle.config, 3);
    let untrained = params.clone();
    let mut gen = corpus(&bundle, 4);
    let trainer = Trainer::new(TrainConfig {
        steps: 60,
        log_every: 10,
        ..Default::default()
    });
    let log = trainer.train(&bundle, &mut params, &mut gen).unwrap();
    assert!(
        log.last_loss() < log.first_loss() - 0.5,
        "loss {} -> {}",
        log.first_loss(),
        log.last_loss()
    );
    let mut held_out = corpus(&bundle, 777);
    let h_trained = EvalHarness::new(&bundle, &params).unwrap();
    let ppl_trained = h_trained.perplexity(&mut held_out, 2).unwrap();
    drop(h_trained);
    let h_raw = EvalHarness::new(&bundle, &untrained).unwrap();
    let mut held_out2 = corpus(&bundle, 777);
    let ppl_raw = h_raw.perplexity(&mut held_out2, 2).unwrap();
    assert!(
        ppl_trained < ppl_raw * 0.5,
        "perplexity {ppl_raw} -> {ppl_trained}"
    );
}

#[test]
fn stun_pipeline_hits_total_sparsity_and_stays_runnable() {
    let Some((_e, bundle)) = tiny() else { return };
    let mut params = ParamSet::init(&bundle.config, 5);
    let mut gen = corpus(&bundle, 6);
    let report = StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: 0.5,
        calib_batches: 2,
    }
    .run(&bundle, &mut params, &mut gen)
    .unwrap();
    assert!(
        (report.final_sparsity - 0.5).abs() < 0.03,
        "final sparsity {}",
        report.final_sparsity
    );
    assert!(report.expert_report.is_some());
    // pruned model still evaluates
    let h = EvalHarness::new(&bundle, &params).unwrap();
    let r = h.full_report(9, 4, 6, 1).unwrap();
    for (name, v) in &r.rows {
        assert!((0.0..=100.0).contains(v), "{name} {v}");
    }
}

#[test]
fn combinatorial_matches_exhaustive_definition_at_n4() {
    // At n=4 / prune 1, the combinatorial baseline must pick the expert
    // whose removal minimises Eq. 4 — verify against a manual scan.
    let Some((_e, bundle)) = tiny() else { return };
    let mut params = ParamSet::init(&bundle.config, 7);
    let mut gen = corpus(&bundle, 8);
    let inputs = combinatorial::capture_moe_inputs(&bundle, &params, &mut gen).unwrap();

    // manual scan on layer 0
    let art = bundle.artifact("layer_recon").unwrap();
    let n = bundle.config.n_experts;
    let full_args = |mask: &Tensor| {
        vec![
            runtime::tensor_to_literal(params.router(0)).unwrap(),
            runtime::tensor_to_literal(params.w1(0)).unwrap(),
            runtime::tensor_to_literal(params.w2(0)).unwrap(),
            runtime::tensor_to_literal(mask).unwrap(),
            runtime::tensor_to_literal(&inputs[0]).unwrap(),
        ]
    };
    let y_full =
        runtime::literal_to_tensor(&art.run(&full_args(&Tensor::ones(&[n]))).unwrap()[0])
            .unwrap();
    let mut best = (f64::INFINITY, usize::MAX);
    for e in 0..n {
        let mut mask = Tensor::ones(&[n]);
        mask.data_mut()[e] = 0.0;
        let y = runtime::literal_to_tensor(&art.run(&full_args(&mask)).unwrap()[0]).unwrap();
        let loss = y_full.fro_dist(&y);
        if loss < best.0 {
            best = (loss, e);
        }
    }

    let report =
        combinatorial::prune_combinatorial(&bundle, &mut params, &inputs, 1).unwrap();
    assert_eq!(report.pruned[0], vec![best.1]);
    assert!((report.losses[0] - best.0).abs() < 1e-6);
    assert!(report.forward_passes >= (n as u64 + 1) * bundle.config.n_layers as u64);
}

#[test]
fn ours_beats_or_matches_random_expert_choice_on_reconstruction() {
    // Sanity on the Taylor ranking: our O(1) choice should give lower
    // layer-0 reconstruction loss than the WORST choice of the same size.
    let Some((_e, bundle)) = tiny() else { return };
    let params = ParamSet::init(&bundle.config, 9);
    let mut gen = corpus(&bundle, 10);
    let inputs = combinatorial::capture_moe_inputs(&bundle, &params, &mut gen).unwrap();
    let art = bundle.artifact("layer_recon").unwrap();
    let n = bundle.config.n_experts;
    let run_mask = |mask: &Tensor| -> f64 {
        let args = vec![
            runtime::tensor_to_literal(params.router(0)).unwrap(),
            runtime::tensor_to_literal(params.w1(0)).unwrap(),
            runtime::tensor_to_literal(params.w2(0)).unwrap(),
            runtime::tensor_to_literal(mask).unwrap(),
            runtime::tensor_to_literal(&inputs[0]).unwrap(),
        ];
        let y = runtime::literal_to_tensor(&art.run(&args).unwrap()[0]).unwrap();
        let full_args = vec![
            runtime::tensor_to_literal(params.router(0)).unwrap(),
            runtime::tensor_to_literal(params.w1(0)).unwrap(),
            runtime::tensor_to_literal(params.w2(0)).unwrap(),
            runtime::tensor_to_literal(&Tensor::ones(&[n])).unwrap(),
            runtime::tensor_to_literal(&inputs[0]).unwrap(),
        ];
        let y_full = runtime::literal_to_tensor(&art.run(&full_args).unwrap()[0]).unwrap();
        y_full.fro_dist(&y)
    };

    let mut ours = params.clone();
    ExpertPruner::prune(
        &mut ours,
        None,
        &ExpertPruneConfig {
            ratio: 0.5,
            ..Default::default()
        },
    );
    let mut ours_mask = Tensor::ones(&[n]);
    for e in 0..n {
        if !ours.is_expert_alive(0, e) {
            ours_mask.data_mut()[e] = 0.0;
        }
    }
    let ours_loss = run_mask(&ours_mask);

    // worst over all 2-subsets
    let mut worst = 0.0f64;
    for subset in combinatorial::subsets(n, 2) {
        let mut mask = Tensor::ones(&[n]);
        for &e in &subset {
            mask.data_mut()[e] = 0.0;
        }
        worst = worst.max(run_mask(&mask));
    }
    assert!(
        ours_loss <= worst + 1e-9,
        "ours {ours_loss} vs worst {worst}"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_eval_scores() {
    let Some((_e, bundle)) = tiny() else { return };
    let mut params = ParamSet::init(&bundle.config, 11);
    params.prune_expert(0, 2);
    let path = std::env::temp_dir().join(format!("stun-it-{}.stz", std::process::id()));
    params
        .to_checkpoint("{}")
        .save(&path)
        .unwrap();
    let loaded = ParamSet::from_checkpoint(
        &bundle.config,
        &stun::checkpoint::Checkpoint::load(&path).unwrap(),
    )
    .unwrap();
    std::fs::remove_file(&path).ok();

    let h1 = EvalHarness::new(&bundle, &params).unwrap();
    let mut suite = stun::eval::TaskSuite::new(bundle.config.vocab, bundle.config.seq, 13);
    let items = suite.mc_items(stun::eval::TaskKind::MmluLike, 8);
    let a = h1.score_mc(&items).unwrap();
    drop(h1);
    let h2 = EvalHarness::new(&bundle, &loaded).unwrap();
    let b = h2.score_mc(&items).unwrap();
    assert_eq!(a, b);
}
