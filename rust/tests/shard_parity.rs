//! Sharded-serving parity gate: splitting a compiled model's experts
//! across N engines must not change a single token.
//!
//! The sharded engine replicates the trunk (attention + router) and
//! partitions the expert slabs by a [`Placement`]; every MoE layer's
//! routed groups execute on their primary shard and merge through the
//! same fixed slot-order reduction as the single-engine path. Greedy
//! decode streams must therefore be **token-for-token identical** to
//! the single-engine executor across shards ∈ {1, 2, 4} × quant ∈
//! {f32, u16} — including generations that slide the decode window
//! mid-stream — with last-position logits pinned at 1e-5. On top of
//! the numerics, placement quality (refined never costs more than
//! round-robin on coactivation fixtures) and byte accounting (per-shard
//! residency sums to the single-engine total; replicas pay once per
//! hosting shard) are pinned here too, along with the failure contract:
//! a mid-stream shard kill with full replica coverage replays the
//! unfailed stream bit for bit, and an uncovered kill is a diagnostic,
//! never a panic or a hang.

use std::time::Duration;
use stun::cluster::DistMatrix;
use stun::model::{ModelConfig, ParamSet};
use stun::net::{FaultPlan, InProcess, LinkModel, LinkSpec};
use stun::pruning::unstructured;
use stun::quant::QuantScheme;
use stun::runtime::session::greedy_token;
use stun::runtime::{CompiledForward, DecodeState};
use stun::shard::{expert_bytes_table, Placement, PlacementStrategy, ShardedEngine};
use stun::sparse::{CompiledModel, SparseConfig};
use stun::tensor::IntTensor;

/// The serving model every parity arm runs: tiny config, 70%
/// unstructured sparsity (CSR kernels engaged), one structurally-dead
/// expert (row-compressed away — its placement slot must cost nothing).
fn serving_model() -> ParamSet {
    let cfg = ModelConfig::test_tiny();
    let mut ps = ParamSet::init(&cfg, 71);
    unstructured::magnitude_prune(&mut ps, 0.7).unwrap();
    ps.prune_expert(0, 2);
    ps
}

fn scfg(quant: QuantScheme) -> SparseConfig {
    SparseConfig {
        quant,
        ..Default::default()
    }
}

/// Greedy session stream through any executor: prefill, then one-token
/// decodes. Returns the tokens and the final step's logits row.
fn stream(exec: &dyn CompiledForward, prompt: &[i32], n_tokens: usize) -> (Vec<i32>, Vec<f32>) {
    let mut state: DecodeState = exec.new_session(1);
    let out = exec.prefill(&mut state, 0, prompt).unwrap();
    let mut toks = vec![greedy_token(out.logits.row(0))];
    let mut last = out.logits.row(0).to_vec();
    for _ in 1..n_tokens {
        let out = exec.decode(&mut state, &[(0, *toks.last().unwrap())]).unwrap();
        last = out.logits.row(0).to_vec();
        toks.push(greedy_token(out.logits.row(0)));
    }
    (toks, last)
}

fn assert_logits_close(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "[{ctx}] logits width");
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() <= 1e-5, "[{ctx}] logits drifted: {x} vs {y}");
    }
}

#[test]
fn sharded_streams_match_single_engine_across_shards_and_quant() {
    let ps = serving_model();
    let cfg = ps.config.clone();
    // in-window generation, and a prompt of seq−3 whose 8-token
    // generation crosses `seq` — the window slides mid-stream and the
    // sharded session must re-prefill exactly like the single engine
    let in_window: Vec<i32> = (0..12).map(|i| 2 + (i % 37)).collect();
    let sliding: Vec<i32> = (0..cfg.seq as i32 - 3).map(|i| 2 + (i % 29)).collect();
    for quant in [QuantScheme::F32, QuantScheme::U16] {
        let single = CompiledModel::compile(&ps, &scfg(quant));
        for n_shards in [1usize, 2, 4] {
            let placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, n_shards);
            let sharded = ShardedEngine::new(&ps, &scfg(quant), placement).unwrap();
            for (label, prompt) in [("in-window", &in_window), ("window-slide", &sliding)] {
                let ctx = format!("{}x{n_shards}/{label}", quant.name());
                let (want, want_logits) = stream(&single, prompt, 8);
                let (got, got_logits) = stream(&sharded, prompt, 8);
                assert_eq!(got, want, "[{ctx}] sharded stream diverged");
                assert_logits_close(&got_logits, &want_logits, &ctx);
            }
        }
    }
}

#[test]
fn serial_and_parallel_sharding_agree_on_full_forwards() {
    // the worker-thread fan-out and the in-process serial path run the
    // same slabs — full-sequence logits must agree bit-for-bit, and
    // both must match the unsharded executor at 1e-5 (they share its
    // arithmetic exactly, so this is equality in practice)
    let ps = serving_model();
    let cfg = ps.config.clone();
    let mut tokens = IntTensor::zeros(&[2, cfg.seq]);
    for (i, t) in tokens.row_mut(0).iter_mut().enumerate() {
        *t = 2 + (i as i32 % 41);
    }
    for (i, t) in tokens.row_mut(1).iter_mut().enumerate() {
        *t = 3 + (i as i32 % 17);
    }
    let single = CompiledModel::compile(&ps, &SparseConfig::default());
    let want = single.fwd_logits(&tokens).unwrap();
    for n_shards in [2usize, 4] {
        let placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, n_shards);
        let parallel =
            ShardedEngine::new(&ps, &SparseConfig::default(), placement.clone()).unwrap();
        let serial = ShardedEngine::from_compiled(
            CompiledModel::compile(&ps, &SparseConfig::default()),
            placement,
            false,
        )
        .unwrap();
        let a = parallel.fwd_logits(&tokens).unwrap();
        let b = serial.fwd_logits(&tokens).unwrap();
        let bits = |t: &stun::tensor::Tensor| -> Vec<u32> {
            t.data().iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(
            bits(&a),
            bits(&b),
            "x{n_shards}: worker threads changed the arithmetic"
        );
        assert_logits_close(a.data(), want.data(), &format!("x{n_shards} vs single"));
    }
}

/// Two-block coactivation fixture: experts {0..n/2} and {n/2..n}
/// coactivate within blocks, never across — the ideal 2-shard cut.
fn block_coact(n_layers: usize, n_experts: usize) -> Vec<DistMatrix> {
    (0..n_layers)
        .map(|l| {
            let mut m = DistMatrix::new(n_experts);
            for i in 0..n_experts {
                for j in (i + 1)..n_experts {
                    if (i < n_experts / 2) == (j < n_experts / 2) {
                        m.set(i, j, 0.1 + 0.01 * (l + i + j) as f64);
                    }
                }
            }
            m
        })
        .collect()
}

#[test]
fn refined_placement_never_costs_more_than_round_robin() {
    let coact = block_coact(2, 8);
    let bytes = vec![vec![1000usize; 8]; 2];
    for n_shards in [2usize, 4] {
        let rr = Placement::round_robin(2, 8, n_shards);
        let refined = Placement::build(
            PlacementStrategy::Refined,
            &coact,
            &bytes,
            n_shards,
            Duration::from_millis(30),
            17,
        )
        .unwrap();
        assert!(
            refined.expected_cross_cost(&coact) <= rr.expected_cross_cost(&coact),
            "x{n_shards}: refined placement worse than round-robin"
        );
    }
    // on the 2-shard instance the two blocks are separable outright
    let two = Placement::build(
        PlacementStrategy::Refined,
        &coact,
        &bytes,
        2,
        Duration::from_millis(30),
        17,
    )
    .unwrap();
    assert_eq!(two.expected_cross_cost(&coact), 0.0);
}

#[test]
fn shard_bytes_sum_to_single_engine_total() {
    // satellite byte-accounting contract: with no replicas, the
    // per-shard resident bytes of both the placement table and the
    // engine slabs partition the single-engine total exactly; the dead
    // expert costs nothing anywhere
    let ps = serving_model();
    let cfg = ps.config.clone();
    for quant in [QuantScheme::F32, QuantScheme::U16] {
        let bytes = expert_bytes_table(&ps, quant);
        let total: usize = bytes.iter().flatten().sum();
        assert!(total > 0);
        assert_eq!(bytes[0][2], 0, "dead expert must cost nothing");
        for n_shards in [2usize, 4] {
            let placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, n_shards);
            let table_loads = placement.shard_bytes(&bytes);
            assert_eq!(table_loads.iter().sum::<usize>(), total);
            let engine = ShardedEngine::new(&ps, &scfg(quant), placement).unwrap();
            let slab_loads = engine.shard_resident_bytes();
            assert_eq!(
                slab_loads,
                table_loads,
                "{} x{n_shards}: engine slabs disagree with the placement table",
                quant.name()
            );
        }
    }
}

#[test]
fn replicated_experts_pay_once_per_hosting_shard() {
    let ps = serving_model();
    let cfg = ps.config.clone();
    let bytes = expert_bytes_table(&ps, QuantScheme::F32);
    let total: usize = bytes.iter().flatten().sum();
    let n_shards = 2usize;
    let mut placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, n_shards);
    // replicate expert 0 of every layer onto the other shard
    let mut load = vec![vec![0.0f64; cfg.n_experts]; cfg.n_layers];
    for row in &mut load {
        row[0] = 1.0;
    }
    placement.replicate_hottest(&load, 1);
    let extra: usize = (0..cfg.n_layers).map(|l| bytes[l][0] * (n_shards - 1)).sum();
    assert!(extra > 0);
    let table_loads = placement.shard_bytes(&bytes);
    assert_eq!(table_loads.iter().sum::<usize>(), total + extra);
    let engine = ShardedEngine::new(&ps, &SparseConfig::default(), placement).unwrap();
    assert_eq!(engine.shard_resident_bytes(), table_loads);
    // and replication must not perturb the stream: groups still execute
    // on their primary shard
    let single = CompiledModel::compile(&ps, &SparseConfig::default());
    let prompt: Vec<i32> = (0..10).map(|i| 2 + (i % 31)).collect();
    let (want, want_logits) = stream(&single, &prompt, 6);
    let (got, got_logits) = stream(&engine, &prompt, 6);
    assert_eq!(got, want, "replication changed the decode stream");
    assert_logits_close(&got_logits, &want_logits, "replicated");
}

/// Replicate every *live* expert onto every other shard — the dead
/// expert owns no weights and must stay replica-free.
fn full_coverage(placement: &mut Placement, bytes: &[Vec<usize>], n_experts: usize) {
    let load: Vec<Vec<f64>> = bytes
        .iter()
        .map(|row| row.iter().map(|&b| if b > 0 { 1.0 } else { 0.0 }).collect())
        .collect();
    placement.replicate_hottest(&load, n_experts);
}

#[test]
fn covered_mid_stream_kill_replays_the_unfailed_stream() {
    // satellite failure-recovery contract: with every live expert
    // replicated on both shards, killing shard 1 between decode rounds
    // promotes its replicas to primaries and the greedy stream finishes
    // bit-identically to a run that never saw the fault
    let ps = serving_model();
    let cfg = ps.config.clone();
    let bytes = expert_bytes_table(&ps, QuantScheme::F32);
    let mut placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, 2);
    full_coverage(&mut placement, &bytes, cfg.n_experts);
    let prompt: Vec<i32> = (0..10).map(|i| 2 + (i % 31)).collect();
    let unfailed = ShardedEngine::new(&ps, &scfg(QuantScheme::F32), placement.clone()).unwrap();
    let (want, want_logits) = stream(&unfailed, &prompt, 8);
    let failed = ShardedEngine::with_transport(
        &ps,
        &scfg(QuantScheme::F32),
        placement,
        Box::new(InProcess),
        Some(FaultPlan { shard: 1, round: 3 }),
    )
    .unwrap();
    let (got, got_logits) = stream(&failed, &prompt, 8);
    assert_eq!(got, want, "covered kill changed the decode stream");
    assert_logits_close(&got_logits, &want_logits, "covered-kill");
    assert!(failed.degraded().is_none(), "full coverage must not degrade");
    let events = failed.take_recovery_events();
    assert_eq!(events.len(), 1, "exactly one recovery event");
    assert_eq!(events[0].dead_shard, 1);
    assert!(events[0].covered(), "all of shard 1's experts had replicas");
    assert!(events[0].promoted > 0, "promotion must have happened");
    // after failover no primary may still point at the dead shard
    let p = failed.placement();
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            assert_ne!(p.primary_shard(l, e), 1, "(layer {l}, expert {e}) still on dead shard");
        }
    }
}

#[test]
fn uncovered_mid_stream_kill_is_a_diagnostic_not_a_hang() {
    // no replicas anywhere: killing shard 0 orphans its live experts.
    // The stream must stop with an actionable error — and keep
    // returning that same error — rather than panicking or hanging.
    let ps = serving_model();
    let cfg = ps.config.clone();
    let placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, 2);
    let engine = ShardedEngine::with_transport(
        &ps,
        &scfg(QuantScheme::F32),
        placement,
        Box::new(InProcess),
        Some(FaultPlan { shard: 0, round: 2 }),
    )
    .unwrap();
    let prompt: Vec<i32> = (0..10).map(|i| 2 + (i % 31)).collect();
    let mut state = engine.new_session(1);
    let out = engine.prefill(&mut state, 0, &prompt).unwrap();
    let mut tok = greedy_token(out.logits.row(0));
    let mut first_err = None;
    for _ in 0..8 {
        match engine.decode(&mut state, &[(0, tok)]) {
            Ok(out) => tok = greedy_token(out.logits.row(0)),
            Err(e) => {
                first_err = Some(e.to_string());
                break;
            }
        }
    }
    let msg = first_err.expect("uncovered kill must surface an error mid-stream");
    assert!(msg.contains("degraded"), "diagnostic lacks mode: {msg}");
    assert!(msg.contains("shard 0"), "diagnostic lacks the dead shard: {msg}");
    assert!(msg.contains("--replicate"), "diagnostic lacks the remedy: {msg}");
    // degraded mode is sticky: the next round repeats the same diagnostic
    let again = engine
        .decode(&mut state, &[(0, tok)])
        .err()
        .expect("degraded mode must persist")
        .to_string();
    assert_eq!(again, msg, "degraded diagnostic drifted between rounds");
    let events = engine.take_recovery_events();
    assert_eq!(events.len(), 1);
    assert!(!events[0].covered(), "uncovered kill must report orphans");
}

#[test]
fn network_aware_refinement_beats_round_robin_under_nonuniform_links() {
    // acceptance criterion: under a nonuniform link model the
    // network-aware refined placement achieves strictly lower expected
    // transfer time than round-robin on the separable block fixture —
    // here the two blocks split cleanly, so refined pays nothing at all
    let coact = block_coact(2, 8);
    let bytes = vec![vec![1000usize; 8]; 2];
    let mut link = LinkModel::zero(2);
    link.set_link(0, 1, LinkSpec::wire(50.0, 10.0));
    link.set_link(1, 0, LinkSpec::wire(200.0, 2.5));
    let msg_bytes = 4096u64;
    let rr = Placement::round_robin(2, 8, 2);
    let refined = Placement::build_net(
        PlacementStrategy::Refined,
        &coact,
        &bytes,
        2,
        &link,
        msg_bytes,
        Duration::from_millis(30),
        17,
    )
    .unwrap();
    let t_rr = rr.expected_transfer_time(&coact, &link, msg_bytes);
    let t_refined = refined.expected_transfer_time(&coact, &link, msg_bytes);
    assert!(t_rr > 0.0, "round-robin must pay for cross-block coactivation");
    assert!(
        t_refined <= t_rr,
        "refined placement transfers slower than round-robin: {t_refined} vs {t_rr}"
    );
    assert_eq!(t_refined, 0.0, "separable blocks must refine to zero transfer");
}

#[test]
fn transfer_meter_counts_activation_bytes_without_spending_time() {
    // structural byte accounting on the serving path: every cross-shard
    // expert activation moves one d_model-float row each way, so the
    // metered total is a whole multiple of 2 * d_model * 4 bytes — and
    // the in-process transport never advances the virtual clock
    let ps = serving_model();
    let cfg = ps.config.clone();
    let placement = Placement::round_robin(cfg.n_layers, cfg.n_experts, 2);
    let engine = ShardedEngine::new(&ps, &scfg(QuantScheme::F32), placement).unwrap();
    let prompt: Vec<i32> = (0..10).map(|i| 2 + (i % 31)).collect();
    let _ = stream(&engine, &prompt, 6);
    let meter = engine.net_meter();
    assert!(meter.total_bytes() > 0, "2-shard round-robin serving must cross shards");
    let quantum = 2 * cfg.d_model as u64 * 4;
    assert_eq!(
        meter.total_bytes() % quantum,
        0,
        "transfer bytes are not a multiple of one round-trip activation row"
    );
    assert_eq!(meter.virtual_time, Duration::ZERO, "in-process transport must be free");
    assert!(meter.layers_metered > 0);
    for lane in meter.active_lanes() {
        assert_ne!(lane.from, lane.to, "diagonal lane metered");
        assert!(lane.messages > 0 && lane.bytes > 0);
    }
}
