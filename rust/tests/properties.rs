//! Property-based tests (hand-rolled generators — proptest is unavailable
//! offline): randomised invariants over the host-side algorithm library.
//! Each property runs across many seeded cases; failures print the seed.

use stun::checkpoint::Checkpoint;
use stun::cluster::{self, DistMatrix};
use stun::model::{ModelConfig, ParamSet};
use stun::pruning::combinatorial::{subset_count, subsets};
use stun::pruning::expert::{ExpertPruneConfig, ExpertPruner};
use stun::pruning::unstructured::{self, ActNorms, UnstructuredConfig, UnstructuredMethod};
use stun::pruning::residual_rate;
use stun::tensor::Tensor;
use stun::util::rng::Rng;

fn random_dist(rng: &mut Rng, n: usize) -> DistMatrix {
    let mut m = DistMatrix::new(n);
    for i in 0..n {
        for j in (i + 1)..n {
            m.set(i, j, rng.f64() * 10.0);
        }
    }
    m
}

#[test]
fn prop_residual_rate_always_composes_to_target() {
    let mut rng = Rng::new(1);
    for case in 0..500 {
        let already = rng.f64() * 0.8;
        let target = rng.f64();
        let r = residual_rate(target, already);
        assert!((0.0..=1.0).contains(&r), "case {case}");
        if target > already {
            let total = already + (1.0 - already) * r;
            assert!((total - target).abs() < 1e-9, "case {case}");
        } else {
            assert_eq!(r, 0.0, "case {case}");
        }
    }
}

#[test]
fn prop_agglomerative_target_exact_count_and_partition() {
    let mut rng = Rng::new(2);
    for case in 0..100 {
        let n = rng.range(2, 24);
        let target = rng.range(1, n + 1);
        let d = random_dist(&mut rng, n);
        let c = cluster::agglomerative_target(&d, target);
        assert_eq!(c.n_clusters, target, "case {case} n={n}");
        // partition: every item in exactly one cluster
        let mut seen = vec![false; n];
        for members in c.clusters() {
            for m in members {
                assert!(!seen[m], "case {case}: duplicate item");
                seen[m] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "case {case}: missing item");
    }
}

#[test]
fn prop_threshold_agglomerative_monotone_in_threshold() {
    let mut rng = Rng::new(3);
    for case in 0..50 {
        let n = rng.range(3, 16);
        let d = random_dist(&mut rng, n);
        let mut last = usize::MAX;
        for t in [0.0, 1.0, 3.0, 6.0, 11.0] {
            let c = cluster::agglomerative(&d, t);
            assert!(
                c.n_clusters <= last,
                "case {case}: clusters increased with looser threshold"
            );
            last = c.n_clusters;
        }
        assert_eq!(cluster::agglomerative(&d, 1e9).n_clusters, 1, "case {case}");
    }
}

#[test]
fn prop_dsatur_colour_classes_are_similarity_cliques() {
    let mut rng = Rng::new(4);
    for case in 0..50 {
        let n = rng.range(2, 14);
        let d = random_dist(&mut rng, n);
        let t = rng.f64() * 10.0;
        let c = cluster::dsatur(&d, t);
        for members in c.clusters() {
            for (ai, &a) in members.iter().enumerate() {
                for &b in &members[ai + 1..] {
                    assert!(
                        d.get(a, b) <= t,
                        "case {case}: dissimilar pair ({a},{b}) share a cluster"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_subsets_length_matches_binomial() {
    let mut rng = Rng::new(5);
    for _ in 0..60 {
        let n = rng.range(1, 12);
        let k = rng.range(0, n + 1);
        assert_eq!(subsets(n, k).len() as u128, subset_count(n, k), "C({n},{k})");
    }
}

#[test]
fn prop_expert_pruner_respects_ratio_and_mask_weight_consistency() {
    let mut rng = Rng::new(6);
    for case in 0..20 {
        let cfg = ModelConfig::test_tiny();
        let mut ps = ParamSet::init(&cfg, rng.next_u64());
        let ratio = [0.25, 0.5, 0.75][case % 3];
        ExpertPruner::prune(
            &mut ps,
            None,
            &ExpertPruneConfig {
                ratio,
                ..Default::default()
            },
        );
        let expect_pruned = ((cfg.n_experts as f64) * ratio).round() as usize;
        for l in 0..cfg.n_layers {
            assert_eq!(
                ps.alive_experts(l).len(),
                cfg.n_experts - expect_pruned,
                "case {case} layer {l}"
            );
            for e in 0..cfg.n_experts {
                let zeroed = ps.expert_theta(l, e).iter().all(|&x| x == 0.0);
                assert_eq!(
                    !ps.is_expert_alive(l, e),
                    zeroed,
                    "case {case}: mask and weights disagree (layer {l} expert {e})"
                );
            }
        }
    }
}

#[test]
fn prop_unstructured_rate_within_tolerance_across_methods() {
    let mut rng = Rng::new(7);
    let cfg = ModelConfig::test_tiny();
    for case in 0..12 {
        let mut ps = ParamSet::init(&cfg, rng.next_u64());
        let rate = 0.1 + 0.8 * rng.f64();
        let method = [
            UnstructuredMethod::Magnitude,
            UnstructuredMethod::Wanda,
            UnstructuredMethod::Owl,
        ][case % 3];
        unstructured::prune(
            &mut ps,
            &ActNorms::uniform(&cfg),
            rate,
            &UnstructuredConfig {
                method,
                ..Default::default()
            },
        )
        .unwrap();
        let s = ps.overall_sparsity();
        assert!(
            (s - rate).abs() < 0.04,
            "case {case} {method:?}: wanted {rate:.3} got {s:.3}"
        );
    }
}

#[test]
fn prop_checkpoint_roundtrip_random_tensors() {
    let mut rng = Rng::new(8);
    for case in 0..20 {
        let mut ckpt = Checkpoint::new(format!("{{\"case\":{case}}}"));
        let n_tensors = rng.range(1, 8);
        for t in 0..n_tensors {
            let ndim = rng.range(0, 4);
            let shape: Vec<usize> = (0..ndim).map(|_| rng.range(1, 9)).collect();
            ckpt.push(format!("t{t}"), Tensor::randn(&shape, &mut rng))
                .unwrap();
        }
        let path =
            std::env::temp_dir().join(format!("stun-prop-{}-{case}.stz", std::process::id()));
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.meta, ckpt.meta);
        assert_eq!(back.names(), ckpt.names());
        for (name, t) in ckpt.iter() {
            assert_eq!(back.get(name).unwrap(), t, "case {case} {name}");
        }
    }
}

#[test]
fn prop_owl_rates_bounded_and_mean_preserving() {
    let mut rng = Rng::new(9);
    let cfg = ModelConfig::test_tiny();
    for case in 0..10 {
        let ps = ParamSet::init(&cfg, rng.next_u64());
        let rate = 0.2 + 0.5 * rng.f64();
        let lambda = 0.08;
        let rates =
            unstructured::owl_layer_rates(&ps, &ActNorms::uniform(&cfg), rate, 5.0, lambda);
        for &r in &rates {
            assert!(
                r >= rate - lambda - 1e-9 && r <= rate + lambda + 1e-9,
                "case {case}: rate {r} outside band around {rate}"
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    use stun::util::json::Json;
    let mut rng = Rng::new(10);
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.f64() * 1e6).round() / 4.0),
            3 => Json::Str(format!("s{}", rng.next_u64() % 1000)),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..200 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} — {text}"));
        assert_eq!(back, v, "case {case}");
    }
}

#[test]
fn prop_tensor_matmul_associates_with_identity() {
    let mut rng = Rng::new(11);
    for case in 0..30 {
        let n = rng.range(1, 10);
        let m = rng.range(1, 10);
        let a = Tensor::randn(&[n, m], &mut rng);
        let mut eye = Tensor::zeros(&[m, m]);
        for i in 0..m {
            *eye.at2_mut(i, i) = 1.0;
        }
        let prod = a.matmul(&eye).unwrap();
        assert_eq!(prod, a, "case {case}");
    }
}
