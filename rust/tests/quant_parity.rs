//! Quantized-storage parity gate — the correctness contract of the
//! `quant` subsystem, end to end:
//!
//! 1. **Weight contract** — per-row absmax quantization reconstructs
//!    every prunable weight within the documented relative bounds
//!    (u16 ≤ 1e-3, u8 ≤ 2e-2), measured on the actual compiled model.
//! 2. **Eval parity** — a u16-quantized compiled `EvalHarness` must
//!    reproduce the dense per-call `EvalReport` row-for-row within 1e-3
//!    (and its perplexity within 1e-3 relative) on the same
//!    unpruned / 70%-CSR / dead-expert trio the f32 parity gate uses;
//!    u8 tracks dense perplexity within a 5% end-to-end drift budget
//!    (its *weight*-level bound is the 2e-2 contract of test 1).
//! 3. **Greedy-stream stability** — u16-compiled decode sessions emit
//!    token streams *identical* to f32-compiled sessions on the
//!    `decode_session` fixtures, every quantized executor's
//!    incremental path replays its own full-recompute path exactly,
//!    and multi-slot layer-major `session_round`s replay the
//!    sequential single-slot sessions exactly (the session kernels are
//!    shared, so there is zero tolerance).
//! 4. **Bytes** — `ExpertStore::working_set_bytes` shrinks ≥1.8× at u16
//!    (and further at u8) for the 70%-sparsity model, and the quant-aware
//!    `CompressionReport` agrees with what the compile pass stores.

use stun::coordinator::ExpertStore;
use stun::data::{CorpusConfig, CorpusGenerator};
use stun::eval::EvalHarness;
use stun::model::{ModelConfig, ParamSet};
use stun::pruning::unstructured;
use stun::quant::QuantScheme;
use stun::runtime::session::greedy_token;
use stun::runtime::{Backend, CompiledForward, DecodeState, NativeBackend};
use stun::sparse::{CompressionReport, SparseConfig};
use stun::tensor::IntTensor;

fn tiny() -> NativeBackend {
    NativeBackend::new(ModelConfig::test_tiny())
}

fn scfg(quant: QuantScheme) -> SparseConfig {
    SparseConfig {
        quant,
        ..Default::default()
    }
}

/// The same model trio the f32 parity gates use: unpruned dense,
/// 70%-unstructured (CSR kernels engaged), and expert-pruned.
fn model_variants(cfg: &ModelConfig) -> Vec<(&'static str, ParamSet)> {
    let base = ParamSet::init(cfg, 41);
    let mut sparse = base.clone();
    unstructured::magnitude_prune(&mut sparse, 0.7).unwrap();
    let mut dead = base.clone();
    dead.prune_expert(0, 1);
    dead.prune_expert(1, 2);
    vec![("dense", base), ("csr-0.7", sparse), ("expert-pruned", dead)]
}

/// 70%-magnitude-pruned params — the headline byte-accounting model.
fn pruned_70(cfg: &ModelConfig) -> ParamSet {
    let mut ps = ParamSet::init(cfg, 41);
    unstructured::magnitude_prune(&mut ps, 0.7).unwrap();
    ps
}

// ---------------------------------------------------------------------------
// 1. Weight-level error contract on real model weights.
// ---------------------------------------------------------------------------

#[test]
fn prunable_weights_requantize_within_documented_bounds() {
    let backend = tiny();
    let ps = pruned_70(backend.config());
    let (d, f) = (backend.config().d_model, backend.config().d_ff);
    for scheme in [QuantScheme::U16, QuantScheme::U8] {
        for (label, data, rows, cols) in [
            ("w1", ps.w1(0).subtensor(0), d, f),
            ("w2", ps.w2(0).subtensor(0), f, d),
            ("wqkv", ps.get("layer0.wqkv").unwrap().data(), d, 3 * d),
        ] {
            let q = stun::quant::QuantMat::compile(data, rows, cols, &scfg(scheme));
            let back = q.to_dense();
            for r in 0..rows {
                let row = &data[r * cols..(r + 1) * cols];
                let brow = &back[r * cols..(r + 1) * cols];
                let absmax = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
                for (x, y) in row.iter().zip(brow) {
                    if *x == 0.0 {
                        // pruned zeros must stay exactly zero
                        assert_eq!(*y, 0.0, "{label} row {r} under {scheme:?}");
                    } else {
                        assert!(
                            ((x - y).abs() as f64) <= scheme.error_bound() * absmax as f64,
                            "{label} row {r} under {scheme:?}: {x} vs {y}"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Eval parity: quantized compiled reports vs the dense per-call path.
// ---------------------------------------------------------------------------

#[test]
fn u16_eval_reports_match_dense_within_1e_3() {
    let backend = tiny();
    let cfg = backend.config().clone();
    for (label, params) in model_variants(&cfg) {
        let dense = EvalHarness::new_dense(&backend, &params).unwrap();
        let quant = EvalHarness::with_config(&backend, &params, &scfg(QuantScheme::U16)).unwrap();
        assert!(quant.uses_compiled(), "[{label}]");
        assert!(
            quant.executor().contains("u16"),
            "[{label}] executor '{}' must be the quantized engine",
            quant.executor()
        );
        let rd = dense.full_report(11, 3, 4, 1).unwrap();
        let rq = quant.full_report(11, 3, 4, 1).unwrap();
        assert_eq!(rd.rows.len(), rq.rows.len());
        for ((nd, vd), (nq, vq)) in rd.rows.iter().zip(&rq.rows) {
            assert_eq!(nd, nq);
            assert!(
                (vd - vq).abs() <= 1e-3,
                "[{label}] {nd}: dense {vd} vs u16 {vq}"
            );
        }
        let mut g1 = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 0x51));
        let mut g2 = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 0x51));
        let pd = dense.perplexity(&mut g1, 2).unwrap();
        let pq = quant.perplexity(&mut g2, 2).unwrap();
        assert!(
            (pd - pq).abs() <= 1e-3 * pd.max(1.0),
            "[{label}] perplexity: dense {pd} vs u16 {pq}"
        );
    }
}

#[test]
fn u8_eval_tracks_dense_within_the_drift_budget() {
    // u8's pinned contract is weight-level (2e-2 per row, test 1); end
    // to end we hold it to a 5% perplexity drift budget — a continuous
    // metric, so quantization noise cannot hide behind accuracy steps.
    let backend = tiny();
    let cfg = backend.config().clone();
    for (label, params) in model_variants(&cfg) {
        let dense = EvalHarness::new_dense(&backend, &params).unwrap();
        let quant = EvalHarness::with_config(&backend, &params, &scfg(QuantScheme::U8)).unwrap();
        assert!(quant.executor().contains("u8"), "[{label}]");
        let mut g1 = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 0x53));
        let mut g2 = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 0x53));
        let pd = dense.perplexity(&mut g1, 2).unwrap();
        let pq = quant.perplexity(&mut g2, 2).unwrap();
        assert!(
            (pd - pq).abs() <= 0.05 * pd.max(1.0),
            "[{label}] perplexity: dense {pd} vs u8 {pq}"
        );
        // reports stay well-formed and bounded on the u8 engine
        let rq = quant.full_report(13, 3, 4, 1).unwrap();
        for (name, v) in &rq.rows {
            assert!((0.0..=100.0).contains(v), "[{label}] {name}: {v}");
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Greedy decode-session stability on the session path.
// ---------------------------------------------------------------------------

/// Greedy stream through a session (`prefill` + one-token `decode`s).
fn session_stream(exec: &dyn CompiledForward, prompt: &[i32], n_tokens: usize) -> Vec<i32> {
    let mut state = exec.new_session(1);
    let out = exec.prefill(&mut state, 0, prompt).unwrap();
    let mut toks = vec![greedy_token(out.logits.row(0))];
    for _ in 1..n_tokens {
        let out = exec.decode(&mut state, &[(0, *toks.last().unwrap())]).unwrap();
        toks.push(greedy_token(out.logits.row(0)));
    }
    toks
}

/// The full-recompute reference loop on the same executor (the inlined
/// fixture from `tests/decode_session.rs`).
fn recompute_stream(exec: &dyn CompiledForward, prompt: &[i32], n_tokens: usize) -> Vec<i32> {
    let cfg = exec.config().clone();
    let (s, v) = (cfg.seq, cfg.vocab);
    let mut seq: Vec<i32> = prompt.to_vec();
    if seq.is_empty() {
        seq.push(stun::data::BOS);
    }
    let mut out = Vec::new();
    for _ in 0..n_tokens {
        let mut win = seq.clone();
        if win.len() >= s {
            win.drain(0..win.len() - (s - 1));
        }
        let mut tokens = IntTensor::zeros(&[1, s]);
        tokens.row_mut(0)[..win.len()].copy_from_slice(&win);
        let (logits, _) = exec.fwd_logits_routed(&tokens).unwrap();
        let pos = win.len() - 1;
        let tok = greedy_token(&logits.data()[pos * v..(pos + 1) * v]);
        out.push(tok);
        seq.push(tok);
    }
    out
}

#[test]
fn u16_greedy_session_streams_are_identical_to_f32() {
    // the decode_session fixtures: in-window, window-slide, long-prompt
    let backend = tiny();
    let cfg = backend.config().clone();
    let fixtures = [("in-window", 12usize, 8usize), ("window-slide", cfg.seq - 3, 8)];
    for (label, params) in model_variants(&cfg) {
        let f32_exec = backend
            .compile_with(&params, &scfg(QuantScheme::F32))
            .unwrap()
            .expect("native compiles");
        let u16_exec = backend
            .compile_with(&params, &scfg(QuantScheme::U16))
            .unwrap()
            .expect("native compiles");
        for (fix, prompt_len, n_tokens) in fixtures {
            let prompt: Vec<i32> = (0..prompt_len as i32).map(|i| 2 + (i % 37)).collect();
            let f32_stream = session_stream(f32_exec.as_ref(), &prompt, n_tokens);
            let u16_stream = session_stream(u16_exec.as_ref(), &prompt, n_tokens);
            assert_eq!(
                u16_stream, f32_stream,
                "[{label}/{fix}] u16 greedy stream diverged from f32"
            );
        }
    }
}

#[test]
fn quantized_incremental_replays_quantized_recompute_exactly() {
    // within one quantized executor the KV-cached session must replay
    // the full-recompute loop token for token — the shared-kernel
    // contract holds at every storage width, zero tolerance
    let backend = tiny();
    let cfg = backend.config().clone();
    for scheme in [QuantScheme::U16, QuantScheme::U8] {
        for (label, params) in model_variants(&cfg) {
            let exec = backend
                .compile_with(&params, &scfg(scheme))
                .unwrap()
                .expect("native compiles");
            for (fix, prompt_len, n_tokens) in
                [("in-window", 12usize, 8usize), ("window-slide", cfg.seq - 3, 6)]
            {
                let prompt: Vec<i32> = (0..prompt_len as i32).map(|i| 2 + (i % 37)).collect();
                let inc = session_stream(exec.as_ref(), &prompt, n_tokens);
                let rec = recompute_stream(exec.as_ref(), &prompt, n_tokens);
                assert_eq!(
                    inc,
                    rec,
                    "[{}/{label}/{fix}] incremental diverged from recompute",
                    scheme.name()
                );
            }
        }
    }
}

#[test]
fn quantized_batched_rounds_match_sequential_sessions_exactly() {
    // two slots stepped in one layer-major round per token must emit
    // the same streams as the slots stepped alone — on every quantized
    // executor the batched dequant temp row regroups the weight
    // traversal but must not change a single reduction, so the greedy
    // streams carry zero tolerance
    let backend = tiny();
    let cfg = backend.config().clone();
    for scheme in [QuantScheme::U16, QuantScheme::U8] {
        for (label, params) in model_variants(&cfg) {
            let exec = backend
                .compile_with(&params, &scfg(scheme))
                .unwrap()
                .expect("native compiles");
            let pa: Vec<i32> = (0..10).map(|i| 3 + (i % 11)).collect();
            let pb: Vec<i32> = (0..17).map(|i| 5 + (i % 7)).collect();
            let n = 6;
            let solo_a = session_stream(exec.as_ref(), &pa, n);
            let solo_b = session_stream(exec.as_ref(), &pb, n);

            let mut state = exec.new_session(2);
            state.begin(0, &pa);
            state.begin(1, &pb);
            let out = exec.session_round(&mut state, &[0, 1]).unwrap();
            let mut ta = greedy_token(out.logits.row(0));
            let mut tb = greedy_token(out.logits.row(1));
            let (mut got_a, mut got_b) = (vec![ta], vec![tb]);
            for _ in 1..n {
                state.push(0, ta);
                state.push(1, tb);
                let out = exec.session_round(&mut state, &[0, 1]).unwrap();
                ta = greedy_token(out.logits.row(0));
                tb = greedy_token(out.logits.row(1));
                got_a.push(ta);
                got_b.push(tb);
            }
            let q = scheme.name();
            assert_eq!(got_a, solo_a, "[{q}/{label}] batched slot 0 diverged");
            assert_eq!(got_b, solo_b, "[{q}/{label}] batched slot 1 diverged");
        }
    }
}

#[test]
fn quantized_prefill_rejects_mismatched_state_like_f32() {
    let backend = tiny();
    let params = ParamSet::init(backend.config(), 41);
    let exec = backend
        .compile_with(&params, &scfg(QuantScheme::U8))
        .unwrap()
        .unwrap();
    let mut other = ModelConfig::test_tiny();
    other.d_model = 32;
    other.n_heads = 1;
    let mut st = DecodeState::new(&other, 1);
    assert!(exec.prefill(&mut st, 0, &[2, 3]).is_err());
}

// ---------------------------------------------------------------------------
// 4. Byte accounting: the ≥1.8× u16 working-set shrink.
// ---------------------------------------------------------------------------

#[test]
fn working_set_shrinks_at_least_1_8x_at_u16_for_the_70pct_model() {
    let backend = tiny();
    let ps = pruned_70(backend.config());
    let ws_f32 = ExpertStore::working_set_bytes(&ps, QuantScheme::F32);
    let ws_u16 = ExpertStore::working_set_bytes(&ps, QuantScheme::U16);
    let ws_u8 = ExpertStore::working_set_bytes(&ps, QuantScheme::U8);
    let shrink = ws_f32 as f64 / ws_u16.max(1) as f64;
    assert!(
        shrink >= 1.8,
        "u16 working set must shrink ≥1.8× (got {shrink:.3}: {ws_f32} -> {ws_u16})"
    );
    assert!(ws_u8 < ws_u16, "u8 {ws_u8} must undercut u16 {ws_u16}");
}

#[test]
fn compression_report_matches_compiled_bytes_per_scheme() {
    let backend = tiny();
    let ps = pruned_70(backend.config());
    for scheme in [QuantScheme::F32, QuantScheme::U16, QuantScheme::U8] {
        let report = CompressionReport::from_params_quant(&ps, scheme);
        // the report's effective bytes and the compile pass's stored
        // bytes come from the one shared sizing rule — exact agreement
        // is what makes ExpertStore budgets honest
        let cm = stun::sparse::CompiledModel::compile(&ps, &scfg(scheme));
        assert_eq!(
            report.bytes_effective,
            cm.stats().bytes_compiled,
            "{}",
            scheme.name()
        );
        assert_eq!(report.quant, scheme);
        assert!(report.ratio() >= 1.0, "{}: {}", scheme.name(), report.ratio());
    }
    let f32_ratio = CompressionReport::from_params_quant(&ps, QuantScheme::F32).ratio();
    let u16_ratio = CompressionReport::from_params_quant(&ps, QuantScheme::U16).ratio();
    let u8_ratio = CompressionReport::from_params_quant(&ps, QuantScheme::U8).ratio();
    assert!(u16_ratio > f32_ratio && u8_ratio > u16_ratio);
}
