"""Pallas kernel for the Wanda importance score: ``|W| * ||X||``.

Wanda (Sun et al. 2024) scores each weight by its magnitude times the L2
norm of its input feature over a calibration set; STUN uses it (and OWL,
which reuses the same scores with layerwise sparsity allocation) as the
unstructured second stage. The score computation itself is
embarrassingly parallel — one VPU multiply per weight with the norm vector
broadcast along output columns — so the kernel is a single-pass tile sweep.

The norms arrive from the ``actnorm_probe`` artifact (sum of squares over
calibration batches, accumulated and square-rooted on the Rust side).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wanda_kernel(w_ref, n_ref, o_ref):
    o_ref[...] = jnp.abs(w_ref[...]) * n_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def wanda_score(w, xnorm, *, block_k=64, interpret=True):
    """Compute Wanda scores ``S = |W| * xnorm[:, None]``.

    Args:
      w:     [K, N] f32 weight matrix (inputs on axis 0).
      xnorm: [K] f32 input-feature L2 norms.
      block_k: row-tile size; must divide K.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns: [K, N] f32 scores.
    """
    k_dim, n_dim = w.shape
    if k_dim % block_k != 0:
        raise ValueError(f"K={k_dim} not divisible by block_k={block_k}")

    grid = (k_dim // block_k,)
    return pl.pallas_call(
        _wanda_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, n_dim), lambda i: (i, 0)),
            pl.BlockSpec((block_k,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_k, n_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k_dim, n_dim), w.dtype),
        interpret=interpret,
    )(w, xnorm)
