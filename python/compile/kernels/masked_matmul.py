"""Pallas kernel for sparsity-masked matmul: ``x @ (w * mask)``.

This is the execution path for *unstructured* pruning (STUN stage 2). The
paper's limitation section notes unstructured sparsity needs specialised
hardware for FLOP savings; like the paper we claim parameter/memory
sparsity and execute dense-compute-sparse-values, with the 0/1 mask fused
into the matmul tile so masked weights never leave VMEM unmasked.

Grid is (M-tiles, N-tiles); the full K dimension rides inside the tile
(model dims here are small enough that a (K, BN) weight slab fits VMEM —
for larger K this would gain a k-loop with an accumulator).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...] * m_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def masked_matmul(x, w, mask, *, block_m=64, block_n=64, interpret=True):
    """Compute ``x @ (w * mask)``.

    Args:
      x:    [M, K] f32.
      w:    [K, N] f32.
      mask: [K, N] f32 0/1 sparsity mask.
      block_m, block_n: output tile sizes; must divide M and N.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns: [M, N] f32.
    """
    m_dim, k_dim = x.shape
    _, n_dim = w.shape
    if m_dim % block_m != 0 or n_dim % block_n != 0:
        raise ValueError(
            f"M={m_dim}, N={n_dim} not divisible by blocks ({block_m},{block_n})"
        )

    grid = (m_dim // block_m, n_dim // block_n)
    return pl.pallas_call(
        _masked_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((k_dim, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((k_dim, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), x.dtype),
        interpret=interpret,
    )(x, w, mask)
