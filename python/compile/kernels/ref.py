"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact reference here, written in
plain ``jax.numpy`` with no Pallas imports. The pytest suite sweeps shapes
and asserts ``assert_allclose(kernel(...), ref(...))``; the L2 model is also
testable against these references by swapping ``use_kernels=False``.
"""

import jax.numpy as jnp


def moe_ffn_ref(x, w1, w2, gates):
    """Gated stacked-expert FFN.

    out[t] = sum_e gates[t, e] * relu(x[t] @ w1[e]) @ w2[e]

    Args:
      x:     [T, D]   token activations (MoE block input, post-LN).
      w1:    [E, D, F] stacked expert up-projections.
      w2:    [E, F, D] stacked expert down-projections.
      gates: [T, E]   routing coefficients r_i(x) masked to the top-k set
                      (zero for non-selected experts), paper Eq. 3.

    Returns: [T, D].
    """
    h = jnp.maximum(jnp.einsum("td,edf->etf", x, w1), 0.0)
    y = jnp.einsum("etf,efd->etd", h, w2)
    return jnp.einsum("te,etd->td", gates, y)


def masked_matmul_ref(x, w, mask):
    """x @ (w * mask) — the unstructured-sparsity execution path.

    Args:
      x:    [M, K]
      w:    [K, N]
      mask: [K, N] 0/1 sparsity mask (Wanda / OWL / magnitude output).
    """
    return x @ (w * mask)


def wanda_score_ref(w, xnorm):
    """Wanda importance score  S_ij = |W_ij| * ||X_j||_2  (Sun et al. 2024).

    Args:
      w:     [K, N] weight matrix (inputs on axis 0).
      xnorm: [K]    L2 norm of each input feature over the calibration set.

    Returns: [K, N] scores; pruning removes the lowest scores within each
    per-output comparison group (axis 0 columns), done on the Rust side.
    """
    return jnp.abs(w) * xnorm[:, None]
