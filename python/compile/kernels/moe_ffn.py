"""Pallas kernel for the MoE hot-spot: the gated stacked-expert FFN.

This is the paper's compute bottleneck — every token flows through top-k
expert MLPs (Eq. 3). The kernel computes

    out[t] = sum_e gates[t, e] * relu(x[t] @ w1[e]) @ w2[e]

with a 2-D grid over (token-block, expert) and VMEM-tiled BlockSpecs.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the expert loop is the
*innermost* grid dimension so the output block for a given token tile is
revisited on consecutive grid steps — the accumulation pattern Mosaic keeps
resident in VMEM. Each step streams one expert's (D, F) / (F, D) weight
pair HBM→VMEM and issues two MXU matmuls. Gating is applied as a cheap VPU
broadcast-multiply on the accumulate.

The kernel runs under ``interpret=True`` here (CPU PJRT cannot execute
Mosaic custom-calls); correctness is pinned to ``ref.moe_ffn_ref`` by the
pytest suite, and real-TPU efficiency is *estimated* from the BlockSpec
footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, w1_ref, w2_ref, g_ref, o_ref):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # [BT, D] @ [D, F] -> [BT, F]  (MXU matmul #1, then VPU relu)
    h = jnp.maximum(jnp.dot(x_ref[...], w1_ref[0]), 0.0)
    # [BT, F] @ [F, D] -> [BT, D]  (MXU matmul #2), gated accumulate
    o_ref[...] += g_ref[...] * jnp.dot(h, w2_ref[0])


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def moe_ffn(x, w1, w2, gates, *, block_t=64, interpret=True):
    """Gated stacked-expert FFN (see module docstring).

    Args:
      x:     [T, D] f32 — MoE block input (flattened batch*seq tokens).
      w1:    [E, D, F] f32 — stacked expert up-projections.
      w2:    [E, F, D] f32 — stacked expert down-projections.
      gates: [T, E] f32 — top-k-masked routing coefficients (Eq. 3).
      block_t: token-tile size; must divide T.
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns: [T, D] f32.
    """
    t_tokens, d_model = x.shape
    n_experts, _, d_ff = w1.shape
    if t_tokens % block_t != 0:
        raise ValueError(f"T={t_tokens} not divisible by block_t={block_t}")

    grid = (t_tokens // block_t, n_experts)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d_model), lambda t, e: (t, 0)),
            pl.BlockSpec((1, d_model, d_ff), lambda t, e: (e, 0, 0)),
            pl.BlockSpec((1, d_ff, d_model), lambda t, e: (e, 0, 0)),
            pl.BlockSpec((block_t, 1), lambda t, e: (t, e)),
        ],
        out_specs=pl.BlockSpec((block_t, d_model), lambda t, e: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((t_tokens, d_model), x.dtype),
        interpret=interpret,
    )(x, w1, w2, gates)


# ---------------------------------------------------------------------------
# Differentiable wrapper.
#
# Pallas interpret-mode kernels cannot be traced by jax.grad (program_id has
# no JVP rule), so the train_step artifact goes through this custom_vjp: the
# forward pass runs the kernel, the backward pass is the closed-form gradient
# of the gated stacked-expert FFN written in jnp (residuals are the inputs;
# the expert hidden activations are recomputed, trading FLOPs for memory
# exactly like flash-style kernels do).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def moe_ffn_op(x, w1, w2, gates):
    """Differentiable gated stacked-expert FFN backed by the Pallas kernel."""
    block_t = min(64, x.shape[0])
    return moe_ffn(x, w1, w2, gates, block_t=block_t)


def _moe_ffn_fwd(x, w1, w2, gates):
    return moe_ffn_op(x, w1, w2, gates), (x, w1, w2, gates)


def _moe_ffn_bwd(res, gbar):
    x, w1, w2, gates = res
    h = jnp.einsum("td,edf->etf", x, w1)  # pre-activation, recomputed
    a = jnp.maximum(h, 0.0)
    y = jnp.einsum("etf,efd->etd", a, w2)
    # d gates[t,e] = <gbar[t], y_e[t]>
    d_gates = jnp.einsum("td,etd->te", gbar, y)
    # d y_e[t] = gates[t,e] * gbar[t]
    dy = jnp.einsum("te,td->etd", gates, gbar)
    d_w2 = jnp.einsum("etf,etd->efd", a, dy)
    da = jnp.einsum("etd,efd->etf", dy, w2)
    dh = da * (h > 0.0)
    d_w1 = jnp.einsum("td,etf->edf", x, dh)
    d_x = jnp.einsum("etf,edf->td", dh, w1)
    return d_x, d_w1, d_w2, d_gates


moe_ffn_op.defvjp(_moe_ffn_fwd, _moe_ffn_bwd)


def vmem_footprint_bytes(d_model, d_ff, block_t, dtype_bytes=4):
    """Static VMEM footprint estimate of one grid step, for DESIGN.md §Perf.

    x-tile + w1-slab + w2-slab + gate-col + out-tile (+ h scratch).
    """
    x_tile = block_t * d_model
    w_slabs = 2 * d_model * d_ff
    gate = block_t
    out_tile = block_t * d_model
    h_scratch = block_t * d_ff
    return dtype_bytes * (x_tile + w_slabs + gate + out_tile + h_scratch)
