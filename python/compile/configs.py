"""Model configurations shared by the AOT compiler, tests, and (via
``manifest.json``) the Rust coordinator.

Each config describes one MoE transformer used to reproduce a row of the
paper's evaluation:

* ``moe-32x``  — many small experts  (Arctic-like regime, Fig. 1 / Fig. 2a)
* ``moe-8x``   — 8 mid-size experts  (Mixtral-8x7B-like, Tab. 1/2, Fig. 2b)
* ``moe-4l``   — few large experts   (Mixtral-8x22B-like, Fig. 2c)
* ``dense``    — E=1 degenerate MoE  (non-MoE model for Fig. 3)
* ``tiny``     — smoke-test config for unit tests and the quickstart example

The three MoE configs hold total expert parameters constant
(E * F = 4096 columns) so that Fig. 2's "gap grows with more, smaller
experts" comparison is at matched capacity, as in the paper.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int  # vocabulary size (includes PAD=0)
    seq: int  # maximum sequence length
    d_model: int
    n_heads: int
    d_ff: int  # per-expert FFN hidden size
    n_experts: int
    top_k: int
    n_layers: int

    # Batch shapes baked into the AOT artifacts. HLO is shape-static, so the
    # Rust side pads batches up to these sizes.
    eval_batch: int = 8
    train_batch: int = 8

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        return asdict(self)


CONFIGS = {
    c.name: c
    for c in [
        ModelConfig(
            name="tiny",
            vocab=256,
            seq=64,
            d_model=64,
            n_heads=2,
            d_ff=64,
            n_experts=4,
            top_k=2,
            n_layers=2,
        ),
        ModelConfig(
            name="moe-32x",
            vocab=512,
            seq=128,
            d_model=128,
            n_heads=4,
            d_ff=128,
            n_experts=32,
            top_k=2,
            n_layers=4,
        ),
        ModelConfig(
            name="moe-8x",
            vocab=512,
            seq=128,
            d_model=128,
            n_heads=4,
            d_ff=512,
            n_experts=8,
            top_k=2,
            n_layers=4,
        ),
        ModelConfig(
            name="moe-4l",
            vocab=512,
            seq=128,
            d_model=128,
            n_heads=4,
            d_ff=1024,
            n_experts=4,
            top_k=2,
            n_layers=4,
        ),
        ModelConfig(
            name="dense",
            vocab=512,
            seq=128,
            d_model=128,
            n_heads=4,
            d_ff=1024,
            n_experts=1,
            top_k=1,
            n_layers=4,
        ),
    ]
}
