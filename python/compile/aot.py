"""AOT compiler: lower every L2 graph to HLO *text* artifacts.

Emits, per model config, ``artifacts/<config>/<artifact>.hlo.txt`` plus a
``manifest.json`` describing the exact input/output ordering so the Rust
runtime can drive the executables blind.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <=
INT_MAX``). The HLO text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--configs tiny,moe-8x,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig

F32 = jnp.float32
I32 = jnp.int32

# Token count for the layer_recon artifact (reconstruction-loss probe used
# by the combinatorial Lu et al. baseline and STUN's validation loop).
RECON_TOKENS = 512


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _spec(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def artifact_defs(cfg: ModelConfig, use_kernels=False):
    """Build {artifact_name: (callable, input_sds, input_specs, output_specs)}.

    Every callable takes a flat ``*args`` list in exactly the manifest
    order; outputs are flat tuples in manifest order.

    ``use_kernels`` selects the Pallas-kernel MoE path vs the numerically
    identical jnp reference. Default artifacts ship the reference path: on
    single-core CPU PJRT the interpret-mode Pallas grid loop lowers to a
    sequential HLO ``while`` that blocks XLA's fusion/parallelism (2.6x
    slower end to end — measured in EXPERIMENTS.md §Perf). The
    ``fwd_loss_kernel`` artifact keeps the kernel path compiled into the
    eval route to prove all three layers compose (exercised by the Rust
    runtime tests and the quickstart example).
    """
    specs = model.param_specs(cfg)
    n_params = len(specs)
    l, e, d, f, v = cfg.n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff, cfg.vocab
    s, be, bt = cfg.seq, cfg.eval_batch, cfg.train_batch

    param_sds = [_sds(shape) for _, shape in specs]
    param_specs_json = [_spec(n, sh) for n, sh in specs]
    mask_sds = _sds((l, e))
    mask_spec = _spec("expert_mask", (l, e))

    defs = {}

    def fwd_logits_factory(batch):
        def fwd_logits(*args):
            params, rest = list(args[:n_params]), args[n_params:]
            expert_mask, tokens = rest
            return (model.forward(cfg, params, expert_mask, tokens, use_kernels=use_kernels),)

        ins = param_sds + [mask_sds, _sds((batch, s), I32)]
        in_specs = param_specs_json + [mask_spec, _spec("tokens", (batch, s), "i32")]
        outs = [_spec("logits", (batch, s, v))]
        return fwd_logits, ins, in_specs, outs

    defs["fwd_logits"] = fwd_logits_factory(be)
    defs["fwd_logits_b1"] = fwd_logits_factory(1)

    def fwd_loss(*args):
        params, rest = list(args[:n_params]), args[n_params:]
        expert_mask, tokens, targets = rest
        mean, (total, count, tok_logp) = model.loss_fn(
            cfg, params, expert_mask, tokens, targets, use_kernels=use_kernels
        )
        return mean, total, count, tok_logp

    defs["fwd_loss"] = (
        fwd_loss,
        param_sds + [mask_sds, _sds((be, s), I32), _sds((be, s), I32)],
        param_specs_json
        + [mask_spec, _spec("tokens", (be, s), "i32"), _spec("targets", (be, s), "i32")],
        [
            _spec("mean_loss", ()),
            _spec("total_nll", ()),
            _spec("token_count", ()),
            _spec("tok_logp", (be, s)),
        ],
    )

    def train_step(*args):
        params = list(args[:n_params])
        m_state = list(args[n_params : 2 * n_params])
        v_state = list(args[2 * n_params : 3 * n_params])
        step, lr, tokens, targets = args[3 * n_params :]
        new_p, new_m, new_v, loss = model.train_step(
            cfg, params, m_state, v_state, step, lr, tokens, targets,
            use_kernels=use_kernels,
        )
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    defs["train_step"] = (
        train_step,
        param_sds * 3
        + [_sds(()), _sds(()), _sds((bt, s), I32), _sds((bt, s), I32)],
        param_specs_json
        + [_spec("m." + n, sh) for n, sh in specs]
        + [_spec("v." + n, sh) for n, sh in specs]
        + [
            _spec("step", ()),
            _spec("lr", ()),
            _spec("tokens", (bt, s), "i32"),
            _spec("targets", (bt, s), "i32"),
        ],
        param_specs_json
        + [_spec("m." + n, sh) for n, sh in specs]
        + [_spec("v." + n, sh) for n, sh in specs]
        + [_spec("loss", ())],
    )

    def router_probe(*args):
        params, rest = list(args[:n_params]), args[n_params:]
        expert_mask, tokens = rest
        return (model.router_probe(cfg, params, expert_mask, tokens, use_kernels=use_kernels),)

    defs["router_probe"] = (
        router_probe,
        param_sds + [mask_sds, _sds((be, s), I32)],
        param_specs_json + [mask_spec, _spec("tokens", (be, s), "i32")],
        [_spec("router_probs", (l, be * s, e))],
    )

    def actnorm_probe(*args):
        params, rest = list(args[:n_params]), args[n_params:]
        expert_mask, tokens = rest
        return model.actnorm_probe(cfg, params, expert_mask, tokens, use_kernels=use_kernels)

    defs["actnorm_probe"] = (
        actnorm_probe,
        param_sds + [mask_sds, _sds((be, s), I32)],
        param_specs_json + [mask_spec, _spec("tokens", (be, s), "i32")],
        [
            _spec("attn_in_sq", (l, d)),
            _spec("moe_in_sq", (l, e, d)),
            _spec("moe_hid_sq", (l, e, f)),
            _spec("head_in_sq", (d,)),
        ],
    )

    def hidden_probe(*args):
        params, rest = list(args[:n_params]), args[n_params:]
        expert_mask, tokens = rest
        return (model.hidden_probe(cfg, params, expert_mask, tokens, use_kernels=use_kernels),)

    defs["hidden_probe"] = (
        hidden_probe,
        param_sds + [mask_sds, _sds((be, s), I32)],
        param_specs_json + [mask_spec, _spec("tokens", (be, s), "i32")],
        [_spec("moe_inputs", (l, be * s, d))],
    )

    def layer_recon(router_w, w1, w2, expert_mask, x):
        return (model.layer_recon(cfg, router_w, w1, w2, expert_mask, x, use_kernels=use_kernels),)

    defs["layer_recon"] = (
        layer_recon,
        [
            _sds((e, d)),
            _sds((e, d, f)),
            _sds((e, f, d)),
            _sds((e,)),
            _sds((RECON_TOKENS, d)),
        ],
        [
            _spec("router", (e, d)),
            _spec("w1", (e, d, f)),
            _spec("w2", (e, f, d)),
            _spec("expert_mask", (e,)),
            _spec("x", (RECON_TOKENS, d)),
        ],
        [_spec("y", (RECON_TOKENS, d))],
    )

    def fwd_loss_kernel(*args):
        params, rest = list(args[:n_params]), args[n_params:]
        expert_mask, tokens, targets = rest
        mean, (total, count, tok_logp) = model.loss_fn(
            cfg, params, expert_mask, tokens, targets, use_kernels=True
        )
        return mean, total, count, tok_logp

    defs["fwd_loss_kernel"] = (
        fwd_loss_kernel,
        list(defs["fwd_loss"][1]),
        list(defs["fwd_loss"][2]),
        list(defs["fwd_loss"][3]),
    )

    return defs


def compile_config(cfg: ModelConfig, out_dir: str, only=None) -> dict:
    """Lower all artifacts for one config; returns the manifest dict."""
    cfg_dir = os.path.join(out_dir, cfg.name)
    os.makedirs(cfg_dir, exist_ok=True)
    manifest = {
        "config": cfg.to_dict(),
        "params": [_spec(n, sh) for n, sh in model.param_specs(cfg)],
        "recon_tokens": RECON_TOKENS,
        "artifacts": {},
    }
    for name, (fn, in_sds, in_specs, out_specs) in artifact_defs(cfg).items():
        if only and name not in only:
            continue
        # keep_unused=True: probe graphs don't consume every parameter
        # (e.g. hidden_probe never touches lm_head); without it jax DCEs
        # those inputs out of the HLO and the manifest arity lies to Rust.
        lowered = jax.jit(fn, keep_unused=True).lower(*in_sds)
        text = to_hlo_text(lowered)
        path = os.path.join(cfg_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": in_specs,
            "outputs": out_specs,
        }
        print(f"  {cfg.name}/{name}: {len(text)} chars, "
              f"{len(in_specs)} inputs, {len(out_specs)} outputs")
    with open(os.path.join(cfg_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default=",".join(CONFIGS),
        help="comma-separated config names (default: all)",
    )
    ap.add_argument("--artifacts", default=None,
                    help="comma-separated artifact names (default: all)")
    args = ap.parse_args()

    only = set(args.artifacts.split(",")) if args.artifacts else None
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        print(f"[aot] lowering config {name}")
        compile_config(cfg, args.out_dir, only=only)
    print("[aot] done")


if __name__ == "__main__":
    main()
