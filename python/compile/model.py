"""L2: the MoE transformer compute graph (build-time JAX, AOT→HLO).

This file defines every computation the Rust coordinator executes through
PJRT. Parameters travel as a *flat ordered list* of arrays whose order is
fixed by :func:`param_specs`; ``aot.py`` writes that order into
``manifest.json`` so the Rust side can lay out checkpoints identically.

Architecture (pre-LN decoder):

    h = embed[tokens] + pos_embed
    for each layer:
        h += attn(rmsnorm(h))                  (causal MHA, jnp)
        h += moe(rmsnorm(h))                   (top-k router + Pallas FFN)
    logits = rmsnorm(h) @ lm_head

MoE routing follows the paper exactly (Eq. 1–3): r(x) = softmax(W x),
T = topk(r), out = Σ_{i∈T} r_i(x) E_i(x) — *no* renormalisation over the
top-k set. Expert pruning is executed via a per-layer ``expert_mask``
input: pruned experts get −1e9 added to their router logit, so the softmax
renormalises over survivors — numerically identical to physically removing
the expert (DESIGN.md §Pruned-model execution).

Unstructured pruning needs no graph support: masks are applied to the
weights host-side (W⊙M gives identical numerics to a masked matmul).
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.moe_ffn import moe_ffn_op
from .kernels import ref

NEG_INF = -1e9
PAD_ID = 0  # token id 0 is padding; loss positions with target==PAD are masked

# AdamW hyperparameters baked into the train_step artifact (lr arrives as a
# runtime scalar input so Rust owns the schedule).
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01


# --------------------------------------------------------------------------
# Parameter layout — the Python<->Rust contract.
# --------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the canonical flat parameter layout."""
    d, f, e, v, s = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.vocab, cfg.seq
    specs = [("embed", (v, d)), ("pos_embed", (s, d))]
    for i in range(cfg.n_layers):
        specs += [
            (f"layer{i}.ln1", (d,)),
            (f"layer{i}.wqkv", (d, 3 * d)),
            (f"layer{i}.wo", (d, d)),
            (f"layer{i}.ln2", (d,)),
            (f"layer{i}.router", (e, d)),
            (f"layer{i}.w1", (e, d, f)),
            (f"layer{i}.w2", (e, f, d)),
        ]
    specs += [("ln_f", (d,)), ("lm_head", (d, v))]
    return specs


def init_params(cfg: ModelConfig, key):
    """Scaled-normal init mirroring rust/src/model (same fan-in scaling;
    values differ — checkpoints, not seeds, are the interchange format)."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


def _unflatten(cfg: ModelConfig, flat):
    """Flat param list -> dict keyed by spec name."""
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


# --------------------------------------------------------------------------
# Building blocks.
# --------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def causal_attention(cfg: ModelConfig, h, wqkv, wo):
    """Standard causal multi-head attention. [B,S,D] -> [B,S,D]."""
    b, s, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    qkv = h @ wqkv  # [B,S,3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(x):
        return x.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)  # [B,H,S,hd]

    q, k, v = heads(q), heads(k), heads(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(causal[None, None] > 0, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ wo


def router_probs(x, router_w, expert_mask):
    """Paper Eq. 1: r(x) = softmax(W x), with pruned experts masked to −inf.

    Args:
      x: [T, D] tokens; router_w: [E, D]; expert_mask: [E] (1=keep, 0=pruned).
    Returns: [T, E] routing probabilities (≈0 for pruned experts; the
    softmax renormalises over survivors, matching physical removal).
    """
    logits = x @ router_w.T + (expert_mask - 1.0) * (-NEG_INF)
    return jax.nn.softmax(logits, axis=-1)


def topk_gates(probs, top_k):
    """Paper Eq. 2–3: zero out all but the top-k probabilities (no renorm).

    Implemented as `top_k` iterations of argmax+mask rather than
    ``jax.lax.top_k``: jax ≥ 0.6 lowers the latter to the HLO ``TopK`` op
    with a ``largest`` attribute that xla_extension 0.5.1's text parser
    rejects. k is 1–2 here, so the unrolled form is also cheap.
    """
    gates = jnp.zeros_like(probs)
    remaining = probs
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)
        onehot = jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype)
        gates = gates + onehot * probs
        remaining = remaining - onehot * 2.0  # probs ≤ 1, so never re-picked
    return gates


# --------------------------------------------------------------------------
# Forward / loss.
# --------------------------------------------------------------------------


def forward(cfg: ModelConfig, flat_params, expert_mask, tokens, use_kernels=True,
            collect=None):
    """Full forward pass.

    Args:
      flat_params: list of arrays ordered by :func:`param_specs`.
      expert_mask: [L, E] f32, 1=keep 0=pruned.
      tokens: [B, S] i32.
      use_kernels: route the MoE FFN through the Pallas kernel (the shipped
        artifacts do); False uses the pure-jnp reference (tests).
      collect: optional dict populated with probe tensors (router probs,
        activation square-sums) — used by the probe artifacts.

    Returns: logits [B, S, V].
    """
    p = _unflatten(cfg, flat_params)
    b, s = tokens.shape
    h = p["embed"][tokens] + p["pos_embed"][None, :s]
    for i in range(cfg.n_layers):
        a_in = rmsnorm(h, p[f"layer{i}.ln1"])
        if collect is not None:
            collect.setdefault("attn_in_sq", []).append(
                jnp.sum(jnp.square(a_in), axis=(0, 1))
            )
        h = h + causal_attention(cfg, a_in, p[f"layer{i}.wqkv"], p[f"layer{i}.wo"])

        m_in = rmsnorm(h, p[f"layer{i}.ln2"])
        x = m_in.reshape(b * s, cfg.d_model)
        if collect is not None:
            collect.setdefault("moe_inputs", []).append(x)
        probs = router_probs(x, p[f"layer{i}.router"], expert_mask[i])
        gates = topk_gates(probs, cfg.top_k)
        if collect is not None:
            collect.setdefault("router_probs", []).append(probs)
            # Wanda norms for expert weights: routed-token square-sums only
            # (tokens an expert never sees shouldn't count toward its norms).
            sel = (gates > 0).astype(x.dtype)
            collect.setdefault("moe_in_sq", []).append(
                jnp.einsum("te,td->ed", sel, jnp.square(x))
            )
            hidden = jnp.maximum(jnp.einsum("td,edf->etf", x, p[f"layer{i}.w1"]), 0.0)
            collect.setdefault("moe_hid_sq", []).append(
                jnp.einsum("te,etf->ef", sel, jnp.square(hidden))
            )
        if use_kernels:
            moe_out = moe_ffn_op(x, p[f"layer{i}.w1"], p[f"layer{i}.w2"], gates)
        else:
            moe_out = ref.moe_ffn_ref(x, p[f"layer{i}.w1"], p[f"layer{i}.w2"], gates)
        h = h + moe_out.reshape(b, s, cfg.d_model)

    h = rmsnorm(h, p["ln_f"])
    if collect is not None:
        collect.setdefault("head_in_sq", []).append(
            jnp.sum(jnp.square(h), axis=(0, 1))
        )
    return h @ p["lm_head"]


def loss_fn(cfg: ModelConfig, flat_params, expert_mask, tokens, targets,
            use_kernels=True):
    """Cross-entropy over non-PAD target positions.

    Returns (mean_loss, (total, count, per_token)) so the Rust eval harness
    can aggregate exact perplexity across ragged batches and score
    multiple-choice answers from per-token log-likelihoods.
    """
    logits = forward(cfg, flat_params, expert_mask, tokens, use_kernels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_logp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    weights = (targets != PAD_ID).astype(jnp.float32)
    total = -jnp.sum(tok_logp * weights)
    count = jnp.maximum(jnp.sum(weights), 1.0)
    return total / count, (total, count, tok_logp * weights)


# --------------------------------------------------------------------------
# Training step (AdamW).
# --------------------------------------------------------------------------


def train_step(cfg: ModelConfig, flat_params, m_state, v_state, step, lr,
               tokens, targets, use_kernels=True):
    """One AdamW step. Returns (new_params, new_m, new_v, loss).

    ``step`` is the 1-based step counter (f32 scalar) for bias correction;
    ``lr`` is the current learning rate — both supplied by the Rust trainer
    so the schedule lives on the coordinator side.
    """

    def scalar_loss(ps):
        # expert_mask is all-ones during training (train dense, prune later)
        mask = jnp.ones((cfg.n_layers, cfg.n_experts), jnp.float32)
        return loss_fn(cfg, ps, mask, tokens, targets, use_kernels)[0]

    loss, grads = jax.value_and_grad(scalar_loss)(flat_params)
    b1c = 1.0 - ADAM_B1**step
    b2c = 1.0 - ADAM_B2**step
    new_params, new_m, new_v = [], [], []
    for (name, _), p_arr, g, m_arr, v_arr in zip(
        param_specs(cfg), flat_params, grads, m_state, v_state
    ):
        m_new = ADAM_B1 * m_arr + (1.0 - ADAM_B1) * g
        v_new = ADAM_B2 * v_arr + (1.0 - ADAM_B2) * jnp.square(g)
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + ADAM_EPS)
        if not name.endswith(("ln1", "ln2", "ln_f")):
            update = update + WEIGHT_DECAY * p_arr
        new_params.append(p_arr - lr * update)
        new_m.append(m_new)
        new_v.append(v_new)
    return new_params, new_m, new_v, loss


# --------------------------------------------------------------------------
# Probe graphs (coactivation + Wanda norms) and the reconstruction probe.
# --------------------------------------------------------------------------


def router_probe(cfg: ModelConfig, flat_params, expert_mask, tokens,
                 use_kernels=True):
    """Router probabilities per layer: [L, B*S, E].

    Rust accumulates coactivation statistics a_{i,j} (Eq. 10) and expert
    load from these.
    """
    collect = {}
    forward(cfg, flat_params, expert_mask, tokens, use_kernels, collect=collect)
    return jnp.stack(collect["router_probs"])


def actnorm_probe(cfg: ModelConfig, flat_params, expert_mask, tokens,
                  use_kernels=True):
    """Per-weight-matrix input square-sums for Wanda/OWL.

    Returns (attn_in_sq [L,D], moe_in_sq [L,E,D], moe_hid_sq [L,E,F],
    head_in_sq [D]). Sums of squares over this batch; Rust accumulates
    across calibration batches and takes sqrt at the end.
    """
    collect = {}
    forward(cfg, flat_params, expert_mask, tokens, use_kernels, collect=collect)
    return (
        jnp.stack(collect["attn_in_sq"]),
        jnp.stack(collect["moe_in_sq"]),
        jnp.stack(collect["moe_hid_sq"]),
        collect["head_in_sq"][0],
    )


def hidden_probe(cfg: ModelConfig, flat_params, expert_mask, tokens,
                 use_kernels=True):
    """Per-layer MoE block inputs: [L, B*S, D].

    The combinatorial expert-pruning baseline (Lu et al. 2024) replays
    these activations through ``layer_recon`` for every candidate expert
    subset; STUN's validation loop reuses them to measure Eq. 4 once.
    """
    collect = {}
    forward(cfg, flat_params, expert_mask, tokens, use_kernels, collect=collect)
    return jnp.stack(collect["moe_inputs"])


def layer_recon(cfg: ModelConfig, router_w, w1, w2, expert_mask, x,
                use_kernels=True):
    """Single MoE layer output M(x; θ−θ_S) for reconstruction loss (Eq. 4).

    The combinatorial baseline (Lu et al. 2024) calls this once per expert
    subset S; the forward-pass counter around these calls measures the
    paper's O(kⁿ/√n) vs O(1) complexity claim.
    """
    probs = router_probs(x, router_w, expert_mask)
    gates = topk_gates(probs, cfg.top_k)
    if use_kernels:
        return moe_ffn_op(x, w1, w2, gates)
    return ref.moe_ffn_ref(x, w1, w2, gates)


# --------------------------------------------------------------------------
# Convenience jitted entry point (tests; aot.py lowers its own closures).
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0,))
def jit_forward(cfg: ModelConfig, flat_params, expert_mask, tokens):
    return forward(cfg, flat_params, expert_mask, tokens)
