# L2 correctness: model graph semantics — routing, masking, loss, training.
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS

CFG = CONFIGS["tiny"]
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def tokens(batch):
    return jnp.asarray(
        RNG.integers(1, CFG.vocab, size=(batch, CFG.seq)), jnp.int32
    )


def full_mask():
    return jnp.ones((CFG.n_layers, CFG.n_experts), jnp.float32)


class TestParamLayout:
    def test_spec_count_and_shapes(self):
        specs = model.param_specs(CFG)
        assert len(specs) == 4 + 7 * CFG.n_layers
        named = dict(specs)
        assert named["embed"] == (CFG.vocab, CFG.d_model)
        assert named["layer0.w1"] == (CFG.n_experts, CFG.d_model, CFG.d_ff)
        assert named["lm_head"] == (CFG.d_model, CFG.vocab)

    def test_init_matches_specs(self, params):
        for (name, shape), arr in zip(model.param_specs(CFG), params):
            assert arr.shape == shape, name


class TestRouting:
    def test_probs_sum_to_one(self, params):
        x = jnp.asarray(RNG.normal(size=(16, CFG.d_model)), jnp.float32)
        w = params[6]  # layer0.router
        p = model.router_probs(x, w, jnp.ones(CFG.n_experts))
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)

    def test_masked_expert_gets_zero_prob(self, params):
        x = jnp.asarray(RNG.normal(size=(16, CFG.d_model)), jnp.float32)
        mask = jnp.ones(CFG.n_experts).at[2].set(0.0)
        p = model.router_probs(x, params[6], mask)
        assert float(p[:, 2].max()) < 1e-12
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)

    def test_topk_gates_keep_k_and_no_renorm(self):
        probs = jnp.asarray([[0.5, 0.3, 0.15, 0.05]], jnp.float32)
        g = model.topk_gates(probs, 2)
        np.testing.assert_allclose(np.asarray(g), [[0.5, 0.3, 0.0, 0.0]], rtol=1e-6)

    def test_mask_equals_physical_removal(self, params):
        # Core execution identity: masking expert e == a router/expert set
        # where e never exists. Compare the masked forward against a forward
        # where the pruned expert's prob is removed pre-softmax by slicing.
        x = jnp.asarray(RNG.normal(size=(8, CFG.d_model)), jnp.float32)
        w = params[6]
        mask = jnp.ones(CFG.n_experts).at[1].set(0.0)
        p_masked = model.router_probs(x, w, mask)
        keep = np.array([i for i in range(CFG.n_experts) if i != 1])
        p_sliced = jax.nn.softmax(x @ w[keep].T, axis=-1)
        np.testing.assert_allclose(
            np.asarray(p_masked[:, keep]), np.asarray(p_sliced), rtol=1e-5, atol=1e-6
        )


class TestForward:
    def test_logits_shape_and_finite(self, params):
        t = tokens(2)
        logits = model.forward(CFG, params, full_mask(), t)
        assert logits.shape == (2, CFG.seq, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_kernel_and_ref_paths_agree(self, params):
        t = tokens(2)
        a = model.forward(CFG, params, full_mask(), t, use_kernels=True)
        b = model.forward(CFG, params, full_mask(), t, use_kernels=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3)

    def test_causality(self, params):
        # Changing a later token must not affect earlier logits.
        t1 = tokens(1)
        t2 = t1.at[0, -1].set((int(t1[0, -1]) % (CFG.vocab - 1)) + 1)
        l1 = model.forward(CFG, params, full_mask(), t1)
        l2 = model.forward(CFG, params, full_mask(), t2)
        np.testing.assert_allclose(
            np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), rtol=1e-4, atol=1e-4
        )

    def test_expert_mask_changes_output(self, params):
        t = tokens(1)
        m = full_mask().at[0, 0].set(0.0).at[1, 2].set(0.0)
        a = model.forward(CFG, params, full_mask(), t)
        b = model.forward(CFG, params, m, t)
        assert float(jnp.abs(a - b).max()) > 1e-6


class TestLoss:
    def test_pad_targets_excluded(self, params):
        t = tokens(2)
        tgt = jnp.roll(t, -1, axis=1)
        tgt_pad = tgt.at[:, CFG.seq // 2 :].set(model.PAD_ID)
        _, (_, count, _) = model.loss_fn(CFG, params, full_mask(), t, tgt_pad)
        assert int(count) == 2 * (CFG.seq // 2)

    def test_loss_near_log_vocab_at_init(self, params):
        t = tokens(4)
        tgt = jnp.roll(t, -1, axis=1)
        mean, _ = model.loss_fn(CFG, params, full_mask(), t, tgt)
        assert abs(float(mean) - np.log(CFG.vocab)) < 1.5


class TestTrainStep:
    def test_loss_decreases_on_repeated_batch(self, params):
        t = tokens(CFG.train_batch)
        tgt = jnp.roll(t, -1, axis=1)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        ps = list(params)
        losses = []
        step_fn = jax.jit(
            lambda ps, m, v, s: model.train_step(
                CFG, ps, m, v, s, jnp.float32(3e-3), t, tgt
            )
        )
        for step in range(8):
            ps, m, v, loss = step_fn(ps, m, v, jnp.float32(step + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.1, losses

    def test_shapes_preserved(self, params):
        t = tokens(CFG.train_batch)
        tgt = jnp.roll(t, -1, axis=1)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        new_p, new_m, new_v, loss = model.train_step(
            CFG, params, m, v, jnp.float32(1), jnp.float32(1e-3), t, tgt
        )
        assert len(new_p) == len(params)
        for a, b in zip(new_p, params):
            assert a.shape == b.shape
        assert loss.shape == ()


class TestProbes:
    def test_router_probe_shape_and_simplex(self, params):
        t = tokens(CFG.eval_batch)
        probs = model.router_probe(CFG, params, full_mask(), t)
        assert probs.shape == (
            CFG.n_layers, CFG.eval_batch * CFG.seq, CFG.n_experts
        )
        np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-4)

    def test_actnorm_probe_shapes_nonneg(self, params):
        t = tokens(CFG.eval_batch)
        attn_sq, moe_in, moe_hid, head = model.actnorm_probe(
            CFG, params, full_mask(), t
        )
        assert attn_sq.shape == (CFG.n_layers, CFG.d_model)
        assert moe_in.shape == (CFG.n_layers, CFG.n_experts, CFG.d_model)
        assert moe_hid.shape == (CFG.n_layers, CFG.n_experts, CFG.d_ff)
        assert head.shape == (CFG.d_model,)
        for arr in (attn_sq, moe_in, moe_hid, head):
            assert float(arr.min()) >= 0.0

    def test_layer_recon_matches_moe_block(self, params):
        x = jnp.asarray(RNG.normal(size=(64, CFG.d_model)), jnp.float32)
        router, w1, w2 = params[6], params[7], params[8]
        mask = jnp.ones(CFG.n_experts)
        y = model.layer_recon(CFG, router, w1, w2, mask, x)
        probs = model.router_probs(x, router, mask)
        gates = model.topk_gates(probs, CFG.top_k)
        from compile.kernels import ref

        expect = ref.moe_ffn_ref(x, w1, w2, gates)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(expect), rtol=1e-4, atol=1e-3
        )
