# AOT layer: artifact emission, manifest contract, HLO-text invariants.
import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import CONFIGS

CFG = CONFIGS["tiny"]


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.compile_config(CFG, str(out))
    return out, manifest


class TestManifest:
    def test_all_artifacts_present(self, emitted):
        _, manifest = emitted
        assert set(manifest["artifacts"]) == {
            "fwd_logits", "fwd_logits_b1", "fwd_loss", "train_step",
            "router_probe", "actnorm_probe", "hidden_probe", "layer_recon",
            "fwd_loss_kernel",
        }

    def test_param_order_matches_model(self, emitted):
        _, manifest = emitted
        names = [p["name"] for p in manifest["params"]]
        assert names == [n for n, _ in model.param_specs(CFG)]

    def test_train_step_io_symmetry(self, emitted):
        _, manifest = emitted
        art = manifest["artifacts"]["train_step"]
        n_p = len(manifest["params"])
        assert len(art["inputs"]) == 3 * n_p + 4
        assert len(art["outputs"]) == 3 * n_p + 1
        # outputs order params..., m..., v..., loss
        assert art["outputs"][-1]["name"] == "loss"
        assert [o["name"] for o in art["outputs"][:n_p]] == [
            p["name"] for p in manifest["params"]
        ]

    def test_manifest_roundtrips_json(self, emitted):
        out, manifest = emitted
        on_disk = json.loads((out / CFG.name / "manifest.json").read_text())
        assert on_disk == manifest


class TestHloText:
    def test_files_exist_and_are_hlo_text(self, emitted):
        out, manifest = emitted
        for name, art in manifest["artifacts"].items():
            text = (out / CFG.name / art["file"]).read_text()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_fwd_logits_entry_arity(self, emitted):
        out, manifest = emitted
        text = (out / CFG.name / "fwd_logits.hlo.txt").read_text()
        n_inputs = len(manifest["artifacts"]["fwd_logits"]["inputs"])
        # each entry parameter shows up as parameter(k)
        for k in range(n_inputs):
            assert f"parameter({k})" in text

    def test_no_serialized_proto_artifacts(self, emitted):
        # Guard against regressing to .serialize() (binary protos break
        # xla_extension 0.5.1 — see aot.py docstring).
        out, _ = emitted
        for f in (out / CFG.name).iterdir():
            if f.suffix == ".txt":
                head = f.read_bytes()[:64]
                assert head.decode("utf-8", errors="strict")


class TestLoweredNumerics:
    """Execute the lowered HLO via the in-process PJRT CPU client and compare
    against direct jax execution — the same check the Rust runtime repeats."""

    def _run_hlo(self, text, args):
        from jax._src.lib import xla_client as xc

        client = xc.make_cpu_client()
        # compile accepts an XlaComputation built from HLO text
        comp = xc.XlaComputation(
            xc._xla.hlo_module_proto_from_text(text).SerializeToString()
        )
        exe = client.compile(comp)
        bufs = [client.buffer_from_pyval(a) for a in args]
        outs = exe.execute(bufs)
        return [o for o in outs]

    def test_layer_recon_roundtrip(self, emitted):
        import numpy as np

        out, manifest = emitted
        text = (out / CFG.name / "layer_recon.hlo.txt").read_text()
        e, d, f = CFG.n_experts, CFG.d_model, CFG.d_ff
        t = manifest["recon_tokens"]
        rng = np.random.default_rng(3)
        router = rng.normal(size=(e, d)).astype(np.float32)
        w1 = rng.normal(size=(e, d, f)).astype(np.float32)
        w2 = rng.normal(size=(e, f, d)).astype(np.float32)
        mask = np.ones((e,), np.float32)
        x = rng.normal(size=(t, d)).astype(np.float32)
        try:
            outs = self._run_hlo(text, [router, w1, w2, mask, x])
        except Exception as exc:  # pragma: no cover - env-specific
            pytest.skip(f"in-process PJRT compile unavailable: {exc}")
        got = np.asarray(outs[0])
        if got.ndim == 0 or got.shape == ():
            pytest.skip("tupled output unpacking differs on this jaxlib")
        expect = model.layer_recon(
            CFG, jnp.asarray(router), jnp.asarray(w1), jnp.asarray(w2),
            jnp.asarray(mask), jnp.asarray(x),
        )
        np.testing.assert_allclose(
            got.reshape(expect.shape), np.asarray(expect), rtol=1e-4, atol=1e-3
        )
