# L1 correctness: Pallas kernels vs pure-jnp oracles — the CORE signal.
#
# hypothesis sweeps shapes; fixed-seed numpy generates data. Tolerances are
# scale-aware (f32 accumulation order differs between the kernel's
# sequential expert loop and the reference einsum).
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.moe_ffn import moe_ffn, moe_ffn_op, vmem_footprint_bytes
from compile.kernels.masked_matmul import masked_matmul
from compile.kernels.wanda import wanda_score

RNG = np.random.default_rng(1234)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def assert_close(a, b, rtol=1e-4, atol=1e-3):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- moe_ffn


class TestMoeFfn:
    @pytest.mark.parametrize("t,d,e,f,bt", [
        (64, 32, 2, 48, 32),
        (128, 64, 4, 96, 64),
        (128, 64, 8, 32, 64),
        (64, 16, 1, 16, 64),   # degenerate dense config
        (192, 48, 3, 64, 64),  # non-power-of-two dims
    ])
    def test_matches_ref(self, t, d, e, f, bt):
        x, w1, w2 = randn(t, d), randn(e, d, f), randn(e, f, d)
        gates = jnp.asarray(RNG.random(size=(t, e)), jnp.float32)
        assert_close(moe_ffn(x, w1, w2, gates, block_t=bt),
                     ref.moe_ffn_ref(x, w1, w2, gates))

    @settings(max_examples=20, deadline=None)
    @given(
        t_blocks=st.integers(1, 4),
        bt=st.sampled_from([16, 32, 64]),
        d=st.sampled_from([8, 32, 64]),
        e=st.integers(1, 8),
        f=st.sampled_from([16, 64]),
    )
    def test_shape_sweep(self, t_blocks, bt, d, e, f):
        t = t_blocks * bt
        x, w1, w2 = randn(t, d), randn(e, d, f), randn(e, f, d)
        gates = jnp.asarray(RNG.random(size=(t, e)), jnp.float32)
        assert_close(moe_ffn(x, w1, w2, gates, block_t=bt),
                     ref.moe_ffn_ref(x, w1, w2, gates))

    def test_zero_gates_give_zero_output(self):
        x, w1, w2 = randn(64, 32), randn(4, 32, 48), randn(4, 48, 32)
        gates = jnp.zeros((64, 4), jnp.float32)
        out = moe_ffn(x, w1, w2, gates, block_t=32)
        assert float(jnp.abs(out).max()) == 0.0

    def test_single_expert_gate_selects_that_expert(self):
        x, w1, w2 = randn(32, 16), randn(3, 16, 24), randn(3, 24, 16)
        gates = jnp.zeros((32, 3), jnp.float32).at[:, 1].set(1.0)
        expect = jnp.maximum(x @ w1[1], 0.0) @ w2[1]
        assert_close(moe_ffn(x, w1, w2, gates, block_t=32), expect)

    def test_gate_linearity(self):
        # out(alpha * g) == alpha * out(g): Eq. 3 is linear in the gates.
        x, w1, w2 = randn(64, 32), randn(4, 32, 32), randn(4, 32, 32)
        g = jnp.asarray(RNG.random(size=(64, 4)), jnp.float32)
        a = moe_ffn(x, w1, w2, 2.5 * g, block_t=32)
        b = 2.5 * moe_ffn(x, w1, w2, g, block_t=32)
        assert_close(a, b)

    def test_indivisible_block_raises(self):
        x, w1, w2 = randn(60, 16), randn(2, 16, 16), randn(2, 16, 16)
        g = jnp.ones((60, 2), jnp.float32)
        with pytest.raises(ValueError):
            moe_ffn(x, w1, w2, g, block_t=64)

    def test_custom_vjp_matches_ref_grads(self):
        import jax

        x, w1, w2 = randn(64, 16), randn(3, 16, 24), randn(3, 24, 16)
        g = jnp.asarray(RNG.random(size=(64, 3)), jnp.float32)

        def f_kernel(x, w1, w2, g):
            return jnp.sum(jnp.sin(moe_ffn_op(x, w1, w2, g)))

        def f_ref(x, w1, w2, g):
            return jnp.sum(jnp.sin(ref.moe_ffn_ref(x, w1, w2, g)))

        gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(x, w1, w2, g)
        gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w1, w2, g)
        for a, b in zip(gk, gr):
            assert_close(a, b)

    def test_vmem_footprint_estimate(self):
        # The §Perf VMEM model: footprint grows linearly in d_ff and block_t.
        small = vmem_footprint_bytes(128, 128, 64)
        big = vmem_footprint_bytes(128, 512, 64)
        assert big > small
        # moe-8x tile must fit a TPU core's ~16 MiB VMEM comfortably.
        assert vmem_footprint_bytes(128, 512, 64) < 16 * 2**20


# ---------------------------------------------------------- masked_matmul


class TestMaskedMatmul:
    @pytest.mark.parametrize("m,k,n,bm,bn", [
        (64, 32, 64, 64, 64),
        (128, 64, 128, 64, 64),
        (64, 16, 192, 32, 64),
    ])
    def test_matches_ref(self, m, k, n, bm, bn):
        x, w = randn(m, k), randn(k, n)
        mask = jnp.asarray((RNG.random(size=(k, n)) > 0.5), jnp.float32)
        assert_close(masked_matmul(x, w, mask, block_m=bm, block_n=bn),
                     ref.masked_matmul_ref(x, w, mask))

    @settings(max_examples=15, deadline=None)
    @given(
        mb=st.integers(1, 3), nb=st.integers(1, 3),
        k=st.sampled_from([8, 32, 64]),
        density=st.floats(0.0, 1.0),
    )
    def test_shape_and_density_sweep(self, mb, nb, k, density):
        m, n = 32 * mb, 32 * nb
        x, w = randn(m, k), randn(k, n)
        mask = jnp.asarray((RNG.random(size=(k, n)) < density), jnp.float32)
        assert_close(masked_matmul(x, w, mask, block_m=32, block_n=32),
                     ref.masked_matmul_ref(x, w, mask))

    def test_all_ones_mask_is_plain_matmul(self):
        x, w = randn(64, 32), randn(32, 64)
        mask = jnp.ones_like(w)
        assert_close(masked_matmul(x, w, mask), x @ w)

    def test_all_zeros_mask_gives_zeros(self):
        x, w = randn(64, 32), randn(32, 64)
        out = masked_matmul(x, w, jnp.zeros_like(w))
        assert float(jnp.abs(out).max()) == 0.0

    def test_masking_host_side_is_equivalent(self):
        # The identity the artifacts rely on: W⊙M applied host-side equals
        # the masked kernel — so Rust can bake masks into checkpoints.
        x, w = randn(64, 32), randn(32, 64)
        mask = jnp.asarray((RNG.random(size=(32, 64)) > 0.7), jnp.float32)
        assert_close(masked_matmul(x, w, mask),
                     masked_matmul(x, w * mask, jnp.ones_like(mask)))


# ------------------------------------------------------------ wanda_score


class TestWandaScore:
    @pytest.mark.parametrize("k,n,bk", [(64, 32, 64), (128, 256, 64), (64, 8, 32)])
    def test_matches_ref(self, k, n, bk):
        w = randn(k, n)
        xnorm = jnp.asarray(RNG.random(size=(k,)) + 0.01, jnp.float32)
        assert_close(wanda_score(w, xnorm, block_k=bk),
                     ref.wanda_score_ref(w, xnorm), rtol=1e-6, atol=0)

    def test_scores_nonnegative(self):
        w, xnorm = randn(64, 32), jnp.asarray(RNG.random(size=(64,)), jnp.float32)
        assert float(wanda_score(w, xnorm).min()) >= 0.0

    def test_zero_norm_kills_row(self):
        w = randn(64, 32)
        xnorm = jnp.ones((64,), jnp.float32).at[3].set(0.0)
        s = wanda_score(w, xnorm)
        assert float(jnp.abs(s[3]).max()) == 0.0

    @settings(max_examples=10, deadline=None)
    @given(kb=st.integers(1, 4), n=st.sampled_from([4, 32, 128]))
    def test_shape_sweep(self, kb, n):
        k = 32 * kb
        w = randn(k, n)
        xnorm = jnp.asarray(RNG.random(size=(k,)), jnp.float32)
        assert_close(wanda_score(w, xnorm, block_k=32),
                     ref.wanda_score_ref(w, xnorm), rtol=1e-6, atol=0)
