//! Serving demo: the coordinator answers a burst of generation requests
//! with continuous batching, on the dense model vs the STUN-pruned model,
//! under a fixed expert-memory budget — the deployment win that motivates
//! MoE pruning in the paper's introduction.
//!
//! ```bash
//! cargo run --release --example serve_pruned [-- --config tiny --requests 24]
//! ```

use std::time::Duration;
use stun::coordinator::{burst_workload, Batcher, ExpertStore};
use stun::prelude::*;
use stun::pruning::unstructured::UnstructuredConfig;
use stun::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let config = args.str_or("config", "tiny");
    let n_requests = args.usize_or("requests", 24)?;

    let backend = stun::report::load_backend(&config)?;
    let backend = backend.as_ref();
    let cfg = backend.config().clone();

    // a lightly-trained model (serving quality is not the point here)
    let mut params = ParamSet::init(&cfg, 42);
    let mut corpus = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 42));
    Trainer::new(stun::train::TrainConfig {
        steps: args.usize_or("steps", 60)?,
        ..Default::default()
    })
    .train(backend, &mut params, &mut corpus)?;

    // STUN-pruned variant
    let mut pruned = params.clone();
    StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: 0.4,
        calib_batches: 2,
    }
    .run(backend, &mut pruned, &mut corpus)?;

    // memory budget (bytes) sized to the pruned working set: the dense
    // model must page experts, the pruned one fits — and pruned experts
    // are cheaper per-expert (CSR bytes), so more of them stay resident.
    // `--quant u16|u8` shrinks the accounting further (quantized serving).
    let quant = QuantScheme::parse(&args.str_or("quant", "f32"))?;
    let budget = ExpertStore::working_set_bytes(&pruned, QuantScheme::F32);
    println!(
        "expert memory budget: {:.0} KB (dense needs {:.0} KB, pruned {:.0} KB, \
         pruned@{} {:.0} KB)\n",
        budget as f64 / 1024.0,
        ExpertStore::working_set_bytes(&params, QuantScheme::F32) as f64 / 1024.0,
        ExpertStore::working_set_bytes(&pruned, QuantScheme::F32) as f64 / 1024.0,
        quant.name(),
        ExpertStore::working_set_bytes(&pruned, quant) as f64 / 1024.0
    );

    println!(
        "{:<12} {:>9} {:>9} {:>12} {:>8} {:>10} {:>10}",
        "model", "mem(KB)", "tok/s", "tok/s(eff)", "swaps", "p50", "p95"
    );
    let mut arms = vec![
        ("dense".to_string(), &params, QuantScheme::F32),
        ("stun-pruned".to_string(), &pruned, QuantScheme::F32),
    ];
    if quant.is_quantized() {
        arms.push((format!("stun+{}", quant.name()), &pruned, quant));
    }
    for (label, ps, scheme) in arms {
        let store = ExpertStore::new(budget, Duration::from_micros(200));
        let scfg = SparseConfig {
            quant: scheme,
            ..Default::default()
        };
        let mut batcher = Batcher::with_config(backend, ps, store, true, true, &scfg)?;
        let queue = burst_workload(&cfg, n_requests, 8, 17);
        let (responses, m) = batcher.serve(queue)?;
        assert_eq!(responses.len(), n_requests);
        println!(
            "{:<12} {:>9.0} {:>9.1} {:>12.1} {:>8} {:>10.1?} {:>10.1?}",
            label,
            ExpertStore::working_set_bytes(ps, scheme) as f64 / 1024.0,
            m.tokens_per_sec(),
            m.effective_tokens_per_sec(),
            m.expert_swaps,
            m.p50_latency,
            m.p95_latency
        );
    }
    println!("\nserve_pruned OK");
    Ok(())
}
