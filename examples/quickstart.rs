//! Quickstart: load artifacts, train a tiny MoE for a handful of steps,
//! STUN-prune it, and evaluate — in under a minute on one CPU core.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use stun::prelude::*;
use stun::pruning::unstructured::UnstructuredConfig;
use stun::runtime;

fn main() -> Result<()> {
    // 1. PJRT engine + the `tiny` artifact bundle (AOT-compiled by
    //    `make artifacts`; python never runs again after that).
    let engine = Engine::new()?;
    let bundle = ModelBundle::load(&engine, "artifacts/tiny")?;
    let cfg = bundle.config.clone();
    println!(
        "model: {} ({} params, {} layers x {} experts)",
        cfg.name,
        cfg.param_count(),
        cfg.n_layers,
        cfg.n_experts
    );

    // 2. Train briefly on the synthetic corpus.
    let mut params = ParamSet::init(&cfg, 42);
    let mut corpus = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 42));
    let trainer = Trainer::new(stun::train::TrainConfig {
        steps: 120,
        ..Default::default()
    });
    let log = trainer.train(&bundle, &mut params, &mut corpus)?;
    println!(
        "trained 120 steps in {:.1}s: loss {:.2} -> {:.2}",
        log.seconds,
        log.first_loss(),
        log.last_loss()
    );

    // 3. Prove the three layers compose: run the *Pallas-kernel* variant
    //    of the loss graph and compare against the reference-path variant.
    let (tokens, targets) = corpus.batch(cfg.eval_batch);
    let mut args = runtime::params_to_literals(&params)?;
    args.push(runtime::expert_mask_literal(&params)?);
    args.push(runtime::int_tensor_to_literal(&tokens)?);
    args.push(runtime::int_tensor_to_literal(&targets)?);
    let ref_loss = runtime::literal_to_f32(&bundle.artifact("fwd_loss")?.run(&args)?[0])?;
    let kern_loss =
        runtime::literal_to_f32(&bundle.artifact("fwd_loss_kernel")?.run(&args)?[0])?;
    println!("loss via jnp reference path : {ref_loss:.6}");
    println!("loss via Pallas kernel path : {kern_loss:.6}");
    assert!(
        (ref_loss - kern_loss).abs() < 1e-3,
        "kernel and reference paths disagree"
    );

    // 4. STUN: expert-prune 25% of experts, then OWL to 40% total sparsity.
    let before = EvalHarness::new(&bundle, &params)?.full_report(7, 16, 16, 1)?;
    let mut pruned = params.clone();
    let pipeline = StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: 0.4,
        calib_batches: 2,
    };
    let report = pipeline.run(&bundle, &mut pruned, &mut corpus)?;
    println!(
        "STUN: expert stage {:.1}% -> final {:.1}% sparsity ({} experts pruned, {} decision fwd passes)",
        report.expert_stage_sparsity * 100.0,
        report.final_sparsity * 100.0,
        report.expert_report.as_ref().map(|r| r.experts_pruned).unwrap_or(0),
        report.expert_report.as_ref().map(|r| r.decision_forward_passes).unwrap_or(0),
    );

    // 5. Evaluate before/after.
    let after = EvalHarness::new(&bundle, &pruned)?.full_report(7, 16, 16, 1)?;
    println!("\n{:<20} {:>8} {:>8}", "task", "dense", "stun@40%");
    for ((name, a), (_, b)) in before.rows.iter().zip(&after.rows) {
        println!("{name:<20} {a:8.1} {b:8.1}");
    }
    println!(
        "{:<20} {:8.1} {:8.1}",
        "Avg(mc)",
        before.mc_average(),
        after.mc_average()
    );
    println!("\nquickstart OK");
    Ok(())
}
