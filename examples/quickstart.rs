//! Quickstart: build a backend, train a tiny MoE for a handful of steps,
//! STUN-prune it, and evaluate — in under a minute on one CPU core, with
//! no artifacts or native libraries required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use stun::prelude::*;
use stun::pruning::unstructured::UnstructuredConfig;

fn main() -> Result<()> {
    // 1. Execution backend. `load_backend` picks the PJRT artifact path
    //    when it is compiled in (`--features pjrt`) and `make artifacts`
    //    has run; otherwise the pure-Rust NativeBackend.
    let backend = stun::report::load_backend("tiny")?;
    let backend = backend.as_ref();
    let cfg = backend.config().clone();
    println!(
        "model: {} via {} ({} params, {} layers x {} experts)",
        cfg.name,
        backend.name(),
        cfg.param_count(),
        cfg.n_layers,
        cfg.n_experts
    );

    // 2. Train briefly on the synthetic corpus.
    let mut params = ParamSet::init(&cfg, 42);
    let mut corpus = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 42));
    let trainer = Trainer::new(stun::train::TrainConfig {
        steps: 120,
        ..Default::default()
    });
    let log = trainer.train(backend, &mut params, &mut corpus)?;
    println!(
        "trained 120 steps in {:.1}s: loss {:.2} -> {:.2}",
        log.seconds,
        log.first_loss(),
        log.last_loss()
    );

    // 3. Prove the execution contracts compose: the mean NLL reported by
    //    `fwd_loss` must match the NLL recomputed host-side from the raw
    //    `fwd_logits` output (two separate graph executions).
    let (tokens, targets) = corpus.batch(cfg.eval_batch);
    let loss = backend.fwd_loss(&params, &tokens, &targets)?;
    let logits = backend.fwd_logits(&params, &tokens)?;
    let mut total = 0f64;
    let mut count = 0f64;
    for r in 0..cfg.eval_batch * cfg.seq {
        let tgt = targets.data()[r];
        if tgt == 0 {
            continue; // PAD target positions are masked from the loss
        }
        let row = &logits.data()[r * cfg.vocab..(r + 1) * cfg.vocab];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = row.iter().map(|&x| ((x - maxv) as f64).exp()).sum::<f64>().ln()
            + maxv as f64;
        total += lse - row[tgt as usize] as f64;
        count += 1.0;
    }
    let recomputed = (total / count.max(1.0)) as f32;
    println!("loss via fwd_loss contract  : {:.6}", loss.mean);
    println!("loss recomputed from logits : {recomputed:.6}");
    assert!(
        (loss.mean - recomputed).abs() < 1e-3,
        "fwd_loss and fwd_logits disagree"
    );

    // 4. STUN: expert-prune 25% of experts, then OWL to 40% total sparsity.
    let before = EvalHarness::new(backend, &params)?.full_report(7, 16, 16, 1)?;
    let mut pruned = params.clone();
    let pipeline = StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: 0.4,
        calib_batches: 2,
    };
    let report = pipeline.run(backend, &mut pruned, &mut corpus)?;
    println!(
        "STUN: expert stage {:.1}% -> final {:.1}% sparsity ({} experts pruned, {} decision fwd passes)",
        report.expert_stage_sparsity * 100.0,
        report.final_sparsity * 100.0,
        report.expert_report.as_ref().map(|r| r.experts_pruned).unwrap_or(0),
        report.expert_report.as_ref().map(|r| r.decision_forward_passes).unwrap_or(0),
    );

    // 5. Evaluate before/after.
    let after = EvalHarness::new(backend, &pruned)?.full_report(7, 16, 16, 1)?;
    println!("\n{:<20} {:>8} {:>8}", "task", "dense", "stun@40%");
    for ((name, a), (_, b)) in before.rows.iter().zip(&after.rows) {
        println!("{name:<20} {a:8.1} {b:8.1}");
    }
    println!(
        "{:<20} {:8.1} {:8.1}",
        "Avg(mc)",
        before.mc_average(),
        after.mc_average()
    );
    println!("\nquickstart OK");
    Ok(())
}
