//! §5 robustness analysis as a runnable binary: measure weight kurtosis
//! K(θ) (Eq. 14) for the same model pruned three ways, reproducing the
//! paper's argument that expert pruning preserves unstructured-pruning
//! headroom while unstructured pruning consumes it.
//!
//! ```bash
//! cargo run --release --example kurtosis_probe
//! ```

use stun::prelude::*;
use stun::pruning::robustness;
use stun::pruning::unstructured::{self, ActNorms, UnstructuredConfig, UnstructuredMethod};
use stun::tensor::stats;

fn main() -> Result<()> {
    let cfg = ModelConfig::test_tiny();
    let base = ParamSet::init(&cfg, 61);
    let k0 = robustness::kurtosis_probe(&base);
    println!("unpruned: sparsity {:>5.1}%  K = {:.3}", 0.0, k0.overall);

    // expert pruning at 50% of experts
    let mut expert = base.clone();
    ExpertPruner::prune(
        &mut expert,
        None,
        &ExpertPruneConfig {
            ratio: 0.5,
            ..Default::default()
        },
    );
    let ke = robustness::kurtosis_probe(&expert);
    println!(
        "expert-pruned: sparsity {:>5.1}%  K = {:.3}  (population subset — Gaussian shape kept)",
        ke.sparsity * 100.0,
        ke.overall
    );

    // unstructured pruning at MATCHED sparsity
    let mut unstr = base.clone();
    unstructured::prune(
        &mut unstr,
        &ActNorms::uniform(&cfg),
        ke.sparsity,
        &UnstructuredConfig {
            method: UnstructuredMethod::Magnitude,
            ..Default::default()
        },
    )?;
    let ku = robustness::kurtosis_probe(&unstr);
    println!(
        "unstructured-pruned: sparsity {:>5.1}%  K = {:.3}  (near-zero weights removed — bimodal drift)",
        ku.sparsity * 100.0,
        ku.overall
    );

    // the §5 mechanism in isolation, on a clean Gaussian
    let mut rng = stun::util::rng::Rng::new(7);
    let gauss: Vec<f32> = (0..200_000).map(|_| rng.normal()).collect();
    println!("\nreference distributions:");
    println!("  N(0,1) sample:           K = {:.3} (theory: 3)", stats::kurtosis(&gauss));
    let rademacher: Vec<f32> = (0..10_000)
        .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
        .collect();
    println!(
        "  symmetric bimodal:       K = {:.3} (theory: 1 — Darlington 1970 minimum)",
        stats::kurtosis(&rademacher)
    );

    assert!(ke.overall > ku.overall, "§5 ordering violated");
    println!("\n§5 holds: K(expert-pruned) = {:.3} > K(unstructured) = {:.3}", ke.overall, ku.overall);
    println!("kurtosis_probe OK");
    Ok(())
}
