//! End-to-end flagship run (EXPERIMENTS.md §E2E): train a MoE LM on the
//! synthetic corpus through the AOT train-step artifact, log the loss
//! curve, collect calibration statistics, STUN-prune at the paper's
//! headline 40% sparsity, and compare against unstructured-only pruning at
//! matched sparsity — the Fig. 1 protocol on a real (small) workload.
//!
//! ```bash
//! cargo run --release --example e2e_stun [-- --config moe-8x --steps 200]
//! ```
//! (add `--features pjrt` plus `make artifacts` to run on the AOT path)

use stun::prelude::*;
use stun::pruning::unstructured::{UnstructuredConfig, UnstructuredMethod};
use stun::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let config = args.str_or("config", "moe-8x");
    let steps = args.usize_or("steps", 200)?;
    let sparsity = args.f64_or("sparsity", 0.4)?;

    let backend = stun::report::load_backend(&config)?;
    let backend = backend.as_ref();
    let cfg = backend.config().clone();
    println!(
        "== e2e: {} via {} ({} params, {}x{} experts) ==",
        cfg.name,
        backend.name(),
        cfg.param_count(),
        cfg.n_layers,
        cfg.n_experts
    );

    // ---- 1. train ---------------------------------------------------------
    let mut params = ParamSet::init(&cfg, 42);
    let mut corpus = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 42));
    let trainer = Trainer::new(stun::train::TrainConfig {
        steps,
        ..Default::default()
    });
    let log = trainer.train(backend, &mut params, &mut corpus)?;
    println!("loss curve (step,loss):\n{}", log.render());
    println!(
        "trained {steps} steps in {:.1}s ({:.2} steps/s)",
        log.seconds,
        steps as f64 / log.seconds
    );

    // ---- 2. evaluate the dense model --------------------------------------
    let h = EvalHarness::new(backend, &params)?;
    let dense_report = h.full_report(11, 24, 24, 2)?;
    let mut held_out =
        CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 999));
    let dense_ppl = h.perplexity(&mut held_out, 4)?;
    drop(h);

    // ---- 3. STUN vs unstructured-only at matched total sparsity -----------
    let mut calib = CorpusGenerator::new(CorpusConfig::for_vocab(cfg.vocab, cfg.seq, 4242));
    let mut stun_params = params.clone();
    let stun_report = StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.25,
            ..Default::default()
        },
        unstructured: UnstructuredConfig::default(),
        total_sparsity: sparsity,
        calib_batches: 4,
    }
    .run(backend, &mut stun_params, &mut calib)?;
    println!(
        "STUN: expert stage {:.1}% sparsity (0 decision fwd passes), final {:.1}%",
        stun_report.expert_stage_sparsity * 100.0,
        stun_report.final_sparsity * 100.0
    );

    let mut owl_params = params.clone();
    StunPipeline {
        expert: ExpertPruneConfig {
            ratio: 0.0,
            ..Default::default()
        },
        unstructured: UnstructuredConfig {
            method: UnstructuredMethod::Owl,
            ..Default::default()
        },
        total_sparsity: sparsity,
        calib_batches: 4,
    }
    .run(backend, &mut owl_params, &mut calib)?;

    // ---- 4. report ---------------------------------------------------------
    let stun_h = EvalHarness::new(backend, &stun_params)?;
    let stun_rep = stun_h.full_report(11, 24, 24, 2)?;
    let stun_ppl = stun_h.perplexity(&mut held_out, 4)?;
    drop(stun_h);
    let owl_h = EvalHarness::new(backend, &owl_params)?;
    let owl_rep = owl_h.full_report(11, 24, 24, 2)?;
    let owl_ppl = owl_h.perplexity(&mut held_out, 4)?;
    drop(owl_h);

    println!(
        "\n{:<20} {:>8} {:>10} {:>10}",
        "task",
        "dense",
        "STUN",
        "OWL-only"
    );
    for i in 0..dense_report.rows.len() {
        println!(
            "{:<20} {:8.1} {:10.1} {:10.1}",
            dense_report.rows[i].0, dense_report.rows[i].1, stun_rep.rows[i].1, owl_rep.rows[i].1
        );
    }
    println!(
        "{:<20} {:8.1} {:10.1} {:10.1}",
        "Avg(mc)",
        dense_report.mc_average(),
        stun_rep.mc_average(),
        owl_rep.mc_average()
    );
    println!("{:<20} {dense_ppl:8.2} {stun_ppl:10.2} {owl_ppl:10.2}", "perplexity");
    println!(
        "\nheadline: at {:.0}% sparsity STUN keeps {:.1} GSM8K-proxy vs {:.1} for unstructured-only",
        sparsity * 100.0,
        stun_rep.rows[0].1,
        owl_rep.rows[0].1
    );
    println!("e2e OK");
    Ok(())
}
